//! Network packet representation.
//!
//! Packets are the unit the router moves. Real INC packets are byte
//! streams on the SERDES links; we carry a structured payload plus an
//! explicit `wire_bytes` so that serialization/credit accounting is
//! byte-accurate without byte-level marshalling on the hot path.

use std::sync::Arc;

use crate::sim::Time;
use crate::topology::NodeId;

/// Unique packet id (for tracing/metrics; also used by in-order channels
/// to reorder out-of-order arrivals).
pub type PacketId = u64;

/// How the packet is routed (§2.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteKind {
    /// Minimal-hop adaptive routing to `Packet::dst`.
    Directed,
    /// Flood to every node; `zmode` is the z-dimension sub-state of the
    /// dimension-ordered flood (see [`crate::router::broadcast_forwards`]).
    Broadcast { zmode: ZMode },
    /// Spanning-tree delivery to `Packet::mcast` (§2.4's "multi-cast"
    /// extension; see [`crate::router::multicast`]).
    Multicast,
}

/// z-dimension broadcast sub-mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZMode {
    /// Normal line propagation along z.
    Line,
    /// Post-cage-jump backfill within a cage (never jumps again).
    Fill,
}

/// Which virtual channel / protocol a packet belongs to: the Packet
/// Demux unit (Fig 5) dispatches on this at the destination.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proto {
    /// Internal (virtual) Ethernet frames (§3.1).
    Ethernet,
    /// Postmaster DMA writes (§3.2). `queue` selects the target queue.
    Postmaster { queue: u8 },
    /// Bridge FIFO words (§3.3). `channel` selects one of ≤32 FIFOs
    /// behind a Bridge FIFO Mux/Demux pair.
    BridgeFifo { channel: u8 },
    /// NetTunnel diagnostic reads/writes (§4.2).
    NetTunnel,
    /// Boot / programming traffic pushed by the PCIe Sandbox (§4.3).
    Boot,
    /// Raw application packets (workloads built directly on the router).
    Raw { tag: u16 },
}

/// Structured payload. `Bytes` is reference-counted so broadcast clones
/// are O(1); the other variants are small.
#[derive(Debug, Clone)]
pub enum Payload {
    Empty,
    Bytes(Arc<Vec<u8>>),
    /// Modeled bulk data: occupies wire/buffer space but carries no
    /// content (used for traffic generators and Ethernet frame bodies).
    Synthetic(u32),
    /// Bridge-FIFO words (already width-masked by the transmit unit).
    Words(Arc<Vec<u64>>),
    /// NetTunnel / RingBus style register access. `reply` marks the
    /// read-response leg travelling back to the requester.
    RegAccess { addr: u64, value: u64, write: bool, reply: bool, req_id: u64 },
    /// Bulk memory image write (Boot protocol, §4.3): `data` lands at
    /// `offset` in the destination's `target` memory.
    Region { target: MemTarget, offset: u64, data: Arc<Vec<u8>> },
    /// Small structured application message.
    U64s([u64; 4]),
}

/// Which per-node memory a Boot region write targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemTarget {
    /// 1 GB program/data DRAM (§2).
    Dram,
    /// FPGA configuration port (bitstream load).
    Fpga,
    /// On-card FLASH chip (persistent bitstream store).
    Flash,
}

impl Payload {
    pub fn bytes(data: Vec<u8>) -> Self {
        Payload::Bytes(Arc::new(data))
    }

    /// Payload length in bytes as it would appear on the wire.
    pub fn wire_len(&self) -> u32 {
        match self {
            Payload::Empty => 0,
            Payload::Bytes(b) => b.len() as u32,
            Payload::Synthetic(n) => *n,
            Payload::Words(w) => (w.len() * 8) as u32,
            Payload::RegAccess { .. } => 18,
            Payload::Region { data, .. } => 9 + data.len() as u32,
            Payload::U64s(_) => 32,
        }
    }
}

/// Fixed per-packet header size on the wire (routing + protocol + length
/// + sequence fields). INC's real header format is not published; 8 bytes
/// is consistent with the Table 1 latency fit (DESIGN.md §3).
pub const HEADER_BYTES: u32 = 8;

/// A packet in flight.
#[derive(Debug, Clone)]
pub struct Packet {
    pub id: PacketId,
    pub src: NodeId,
    /// Destination (ignored for broadcast).
    pub dst: NodeId,
    pub route: RouteKind,
    pub proto: Proto,
    pub payload: Payload,
    /// Total bytes this packet occupies on a link (header + payload).
    pub wire_bytes: u32,
    /// Injection timestamp (for latency metrics).
    pub injected_at: Time,
    /// Per-(src, proto) sequence number, for channels that reorder.
    pub seq: u64,
    /// Hops traversed so far (metrics / TTL safety).
    pub hops: u32,
    /// Remaining multicast destinations (None for unicast/broadcast).
    pub mcast: Option<std::sync::Arc<Vec<NodeId>>>,
    /// For `Proto::Ethernet`: the in-flight frame, owned by the packet
    /// itself so internal-Ethernet traffic can cross shard boundaries
    /// (the packet moves between per-shard arenas *by value*; a
    /// transmit-side side table could not follow it). Boxed to keep the
    /// arena slot small; `None` for every other protocol.
    pub eth_frame: Option<Box<crate::channels::ethernet::EthFrame>>,
}

impl Packet {
    pub fn new(
        id: PacketId,
        src: NodeId,
        dst: NodeId,
        route: RouteKind,
        proto: Proto,
        payload: Payload,
        now: Time,
    ) -> Self {
        let wire_bytes = HEADER_BYTES + payload.wire_len();
        Packet {
            id,
            src,
            dst,
            route,
            proto,
            payload,
            wire_bytes,
            injected_at: now,
            seq: 0,
            hops: 0,
            mcast: None,
            eth_frame: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_size_includes_header() {
        let p = Packet::new(
            1,
            NodeId(0),
            NodeId(1),
            RouteKind::Directed,
            Proto::Raw { tag: 0 },
            Payload::bytes(vec![0u8; 100]),
            0,
        );
        assert_eq!(p.wire_bytes, 108);
    }

    #[test]
    fn one_word_bridge_fifo_packet_is_16_bytes() {
        // This is the packet size the Table 1 calibration assumes.
        let p = Packet::new(
            1,
            NodeId(0),
            NodeId(1),
            RouteKind::Directed,
            Proto::BridgeFifo { channel: 0 },
            Payload::Words(Arc::new(vec![42])),
            0,
        );
        assert_eq!(p.wire_bytes, 16);
    }

    #[test]
    fn payload_wire_lengths() {
        assert_eq!(Payload::Empty.wire_len(), 0);
        assert_eq!(Payload::U64s([0; 4]).wire_len(), 32);
        assert_eq!(Payload::Synthetic(1500).wire_len(), 1500);
        assert_eq!(
            Payload::RegAccess { addr: 0, value: 0, write: true, reply: false, req_id: 0 }
                .wire_len(),
            18
        );
    }
}
