//! NetTunnel (§4.2): Ring-Bus semantics over the main packet fabric.
//!
//! Reads and writes to the full 4 GB address space of any node in the
//! system, carried as `Proto::NetTunnel` packets through the ordinary
//! router (directed or broadcast). Read requests generate a reply packet
//! routed back to the requester; results are collected in
//! [`crate::network::Network::tunnel_results`] keyed by request id.
//!
//! Also the home of the Boot protocol handler (bulk image loads pushed
//! by the PCIe Sandbox, §4.3).

use std::sync::Arc;

use crate::network::{App, Network};
use crate::router::{MemTarget, Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;

impl Network {
    /// Write a word to `addr` on `dst` through the fabric.
    pub fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        self.metrics.record_mode("net_tunnel", 8);
        let payload =
            Payload::RegAccess { addr, value, write: true, reply: false, req_id: 0 };
        self.send_directed(src, dst, Proto::NetTunnel, payload);
    }

    /// Broadcast-write a word to the same `addr` on every node.
    pub fn tunnel_broadcast_write(&mut self, src: NodeId, addr: u64, value: u64) {
        self.metrics.record_mode("net_tunnel", 8);
        let payload =
            Payload::RegAccess { addr, value, write: true, reply: false, req_id: 0 };
        self.send_broadcast(src, Proto::NetTunnel, payload);
    }

    /// Issue a read of `addr` on `dst`; the result appears in
    /// `tunnel_results[req_id]` once the reply packet lands.
    pub fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64 {
        self.metrics.record_mode("net_tunnel", 8);
        let req_id = self.next_packet_id() | 1 << 62;
        let payload =
            Payload::RegAccess { addr, value: 0, write: false, reply: false, req_id };
        self.send_directed(src, dst, Proto::NetTunnel, payload);
        req_id
    }

    /// Execute a tunnel access at `node` (scheduled by the Packet
    /// Demux). `app` sees writes that land on an open `Tunnel`
    /// endpoint's mailbox register as messages.
    pub(crate) fn tunnel_exec(&mut self, node: NodeId, packet: Packet, app: &mut dyn App) {
        let now = self.now();
        match packet.payload {
            Payload::RegAccess { addr, value, write, reply, req_id } => {
                if reply {
                    // Read response arriving back at the requester.
                    self.tunnel_results.insert(req_id, value);
                } else if write {
                    let n = self.node_mut(node);
                    n.write_addr(addr, value, now);
                    n.tick_boot(now);
                    if let Some((ep, msg)) =
                        self.comm_capture_tunnel(node, packet.src, addr, value)
                    {
                        self.app_scope(app, |net, app| {
                            net.comm_deliver(app, ep, msg);
                        });
                    }
                } else {
                    let v = self.node(node).read_addr(addr, now);
                    let payload = Payload::RegAccess {
                        addr,
                        value: v,
                        write: false,
                        reply: true,
                        req_id,
                    };
                    // The reply's packet id is derived from the request
                    // id rather than drawn from the id counter: id
                    // assignment inside an event handler would depend
                    // on dispatch order, which the sharded engine does
                    // not share with the serial one (bit 63 marks the
                    // reply leg; bit 62 already marks tunnel requests).
                    let reply = Packet::new(
                        req_id | 1 << 63,
                        node,
                        packet.src,
                        RouteKind::Directed,
                        Proto::NetTunnel,
                        payload,
                        now,
                    );
                    self.inject(reply);
                }
            }
            _ => unreachable!("tunnel packet without RegAccess payload"),
        }
    }

    /// Boot-protocol delivery (§4.3): bulk image chunk at a node.
    pub(crate) fn boot_deliver(&mut self, node: NodeId, packet: Packet) {
        let now = self.now();
        match &packet.payload {
            Payload::Region { target, offset, data } => {
                self.apply_region(node, *target, *offset, data.clone(), now)
            }
            _ => unreachable!("boot packet without Region payload"),
        }
    }

    /// Apply one image chunk to a node's DRAM / FPGA / FLASH, modelling
    /// the local programming time for the latter two.
    pub(crate) fn apply_region(
        &mut self,
        node: NodeId,
        target: MemTarget,
        offset: u64,
        data: Arc<Vec<u8>>,
        now: Time,
    ) {
        let p = self.cfg.programming;
        let n = self.node_mut(node);
        match target {
            MemTarget::Dram => n.dram.write_region(offset, data),
            MemTarget::Fpga => {
                // `offset` carries the bitstream build id (configuration
                // is whole-image; there is no meaningful offset).
                let t = (data.len() as f64 / p.fpga_config_bytes_per_s * 1e9) as Time;
                let start = now.max(n.fpga_done_at);
                n.fpga_done_at = start + t;
                n.fpga_image = Some((offset, data));
            }
            MemTarget::Flash => {
                let t = (data.len() as f64 / p.flash_write_bytes_per_s * 1e9) as Time;
                let start = now.max(n.flash_done_at);
                n.flash_done_at = start + t;
                n.flash_image = Some(data);
            }
        }
    }

    /// Convenience: fetch a completed tunnel read result.
    pub fn tunnel_result(&self, req_id: u64) -> Option<u64> {
        self.tunnel_results.get(&req_id).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NullApp;
    use crate::node::regs;
    use crate::topology::Coord;

    #[test]
    fn remote_write_then_read_roundtrip() {
        let mut net = Network::card();
        let host = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let target = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        net.tunnel_write(host, target, regs::SCRATCH0, 0xFEED);
        net.run_to_quiescence(&mut NullApp);
        let req = net.tunnel_read(host, target, regs::SCRATCH0);
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.tunnel_result(req), Some(0xFEED));
    }

    #[test]
    fn reads_reach_hardware_registers() {
        let mut net = Network::card();
        let host = NodeId(0);
        let target = NodeId(13);
        let req = net.tunnel_read(host, target, regs::TEMP);
        net.run_to_quiescence(&mut NullApp);
        let expected = net.nodes[13].read_addr(regs::TEMP, 0);
        assert_eq!(net.tunnel_result(req), Some(expected));
    }

    #[test]
    fn broadcast_write_hits_every_node() {
        let mut net = Network::card();
        let host = NodeId(0);
        net.tunnel_broadcast_write(host, regs::SCRATCH0 + 8, 0xAA);
        net.run_to_quiescence(&mut NullApp);
        for n in 0..27 {
            assert_eq!(
                net.nodes[n].read_addr(regs::SCRATCH0 + 8, net.now()),
                0xAA,
                "node {n}"
            );
        }
    }

    #[test]
    fn boot_broadcast_boots_all_nodes() {
        let mut net = Network::card();
        net.tunnel_broadcast_write(NodeId(0), regs::BOOT_CMD, 1);
        net.run_to_quiescence(&mut NullApp);
        let t = net.now() + 3 * crate::sim::SEC;
        for n in 0..27 {
            assert_eq!(net.nodes[n].read_addr(regs::BOOT_STATUS, t), 2, "node {n}");
        }
    }

    #[test]
    fn region_applies_with_programming_delay() {
        let mut net = Network::card();
        let img = Arc::new(vec![0u8; 1024 * 1024]);
        net.apply_region(NodeId(3), MemTarget::Fpga, 0x99, img.clone(), 0);
        let n = &net.nodes[3];
        assert!(n.fpga_done_at > 0);
        assert_eq!(n.read_addr(regs::BUILD_ID, n.fpga_done_at), 0x99);
        assert_eq!(n.read_addr(regs::BUILD_ID, n.fpga_done_at - 1), 0);
    }
}
