//! Ring Bus (§4.2): dedicated per-card sideband channel.
//!
//! 27 unidirectional point-to-point links form a ring through all nodes
//! of a card. Requests (and read responses) forward through intervening
//! nodes with no processor involvement; broadcast writes forward a write
//! command all the way around the ring. Because it is independent of the
//! (possibly-under-development) main router logic, it stays usable when
//! the network fabric is broken — the reason it coexists with NetTunnel.
//!
//! Operations here are synchronous model functions: they touch node
//! state directly and *return* the bus latency, which callers (the PCIe
//! Sandbox, mainly) accumulate onto their own clocks.

use crate::network::Network;
use crate::sim::Time;
use crate::topology::NodeId;

/// Position of a node in its card's ring (ring order = Fig 1 node-number
/// order, cyclic).
fn ring_index(nodes: &[NodeId], n: NodeId) -> usize {
    nodes.iter().position(|&x| x == n).expect("node not on card")
}

/// Hops along the unidirectional ring from `from` to `to`.
fn ring_hops(len: usize, from: usize, to: usize) -> u32 {
    ((to + len - from) % len) as u32
}

impl Network {
    /// Read `addr` on `target` via the Ring Bus, requested by
    /// `requester` (both must be on `card`). Returns (value, latency):
    /// request forwards to the target, response continues around the
    /// ring back to the requester — a full loop of 27 hops in total,
    /// regardless of positions.
    pub fn ring_read(
        &mut self,
        card: (u32, u32, u32),
        requester: NodeId,
        target: NodeId,
        addr: u64,
    ) -> (u64, Time) {
        let nodes = self.topo.card_nodes(card);
        let from = ring_index(&nodes, requester);
        let to = ring_index(&nodes, target);
        let now = self.now();
        let value = self.node(target).read_addr(addr, now);
        let hops = ring_hops(nodes.len(), from, to) + ring_hops(nodes.len(), to, from);
        (value, hops as Time * self.cfg.ringbus.hop)
    }

    /// Write via the Ring Bus. Latency is the forward path only (posted
    /// write).
    pub fn ring_write(
        &mut self,
        card: (u32, u32, u32),
        requester: NodeId,
        target: NodeId,
        addr: u64,
        value: u64,
    ) -> Time {
        let nodes = self.topo.card_nodes(card);
        let from = ring_index(&nodes, requester);
        let to = ring_index(&nodes, target);
        let now = self.now();
        let n = self.node_mut(target);
        n.write_addr(addr, value, now);
        n.tick_boot(now);
        ring_hops(nodes.len(), from, to) as Time * self.cfg.ringbus.hop
    }

    /// Broadcast write: the command forwards all the way around the
    /// ring, writing at every node.
    pub fn ring_broadcast_write(
        &mut self,
        card: (u32, u32, u32),
        _requester: NodeId,
        addr: u64,
        value: u64,
    ) -> Time {
        let nodes = self.topo.card_nodes(card);
        let now = self.now();
        for &n in &nodes {
            let st = self.node_mut(n);
            st.write_addr(addr, value, now);
            st.tick_boot(now);
        }
        nodes.len() as Time * self.cfg.ringbus.hop
    }

    /// The Sandbox's 'read all' (§4.3): same address on every node of
    /// the card, collected in ring order in a single loop.
    pub fn ring_read_all(
        &mut self,
        card: (u32, u32, u32),
        requester: NodeId,
        addr: u64,
    ) -> (Vec<(NodeId, u64)>, Time) {
        let nodes = self.topo.card_nodes(card);
        let now = self.now();
        let mut out = Vec::with_capacity(nodes.len());
        let start = ring_index(&nodes, requester);
        for k in 0..nodes.len() {
            let n = nodes[(start + k) % nodes.len()];
            out.push((n, self.node(n).read_addr(addr, now)));
        }
        out.sort_by_key(|(n, _)| n.0);
        (out, nodes.len() as Time * self.cfg.ringbus.hop)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::regs;

    #[test]
    fn ring_hops_wraps() {
        assert_eq!(ring_hops(27, 0, 5), 5);
        assert_eq!(ring_hops(27, 5, 0), 22);
        assert_eq!(ring_hops(27, 13, 13), 0);
    }

    #[test]
    fn read_write_roundtrip_with_latency() {
        let mut net = Network::card();
        let card = (0, 0, 0);
        let (a, b) = (NodeId(0), NodeId(9));
        let wl = net.ring_write(card, a, b, regs::SCRATCH0, 123);
        assert_eq!(wl, 9 * net.cfg.ringbus.hop);
        let (v, rl) = net.ring_read(card, a, b, regs::SCRATCH0);
        assert_eq!(v, 123);
        // Full loop for read: request + response = 27 hops.
        assert_eq!(rl, 27 * net.cfg.ringbus.hop);
    }

    #[test]
    fn broadcast_write_all_nodes() {
        let mut net = Network::card();
        net.ring_broadcast_write((0, 0, 0), NodeId(0), regs::SCRATCH0, 7);
        for n in 0..27 {
            assert_eq!(net.nodes[n].read_addr(regs::SCRATCH0, 0), 7);
        }
    }

    #[test]
    fn read_all_returns_every_node_sorted() {
        let mut net = Network::card();
        let (vals, lat) = net.ring_read_all((0, 0, 0), NodeId(0), regs::EEPROM_SERIAL);
        assert_eq!(vals.len(), 27);
        assert_eq!(lat, 27 * net.cfg.ringbus.hop);
        for (i, (n, v)) in vals.iter().enumerate() {
            assert_eq!(n.0 as usize, i);
            assert_eq!(*v, 0x1BC0_0000 + i as u64);
        }
    }

    #[test]
    fn ring_is_per_card_on_inc3000() {
        let mut net = Network::inc3000();
        // Card (1,0,0) nodes are 27..54 in x-major terms; use card_nodes.
        let card = (1, 0, 0);
        let nodes = net.topo.card_nodes(card);
        let lat = net.ring_write(card, nodes[0], nodes[26], regs::SCRATCH0, 1);
        assert_eq!(lat, 26 * net.cfg.ringbus.hop);
        // Only that card's node got the write.
        assert_eq!(net.nodes[nodes[26].0 as usize].read_addr(regs::SCRATCH0, 0), 1);
        assert_eq!(net.nodes[0].read_addr(regs::SCRATCH0, 0), 0);
    }
}
