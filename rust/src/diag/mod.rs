//! Diagnostic capabilities (§4): JTAG, Ring Bus, NetTunnel, PCIe Sandbox.
//!
//! A development platform needs visibility while the reconfigurable
//! hardware, system software and application software all evolve
//! concurrently. INC layers four mechanisms, from most primitive to most
//! convenient:
//!
//! * [`jtag`] — a per-card daisy chain through all 27 Zynqs: always
//!   works, painfully slow (15 min to configure a card's FPGAs, >5 h for
//!   its FLASH chips — §4.3's numbers, reproduced by bench E7).
//! * [`ringbus`] — a dedicated 27-link sideband ring on each card, with
//!   read/write/broadcast-write to any address on any node, routed
//!   entirely in hardware.
//! * [`nettunnel`] — the same semantics carried over the main packet
//!   fabric, so it spans the whole system (but depends on the very
//!   router logic one may be debugging — which is why the Ring Bus is
//!   not superfluous, as the paper notes).
//! * [`sandbox`] — the host-side interactive utility speaking PCIe to
//!   node (000): read/write/read-all, boot broadcast, FPGA/FLASH
//!   programming, UART attach, EEPROM/temperature queries.

pub mod jtag;
pub mod nettunnel;
pub mod ringbus;
pub mod sandbox;
