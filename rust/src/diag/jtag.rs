//! JTAG (§4.1): one daisy chain through all 27 Zynqs of a card.
//!
//! Both the ARM (via its Debug Access Port) and the FPGA appear as
//! devices on the chain, so JTAG can configure FPGAs, load code, drive
//! ChipScope and debug ARM software — but serially, through a single
//! slow chain, and **only on one card** (§4.3). The programming-time
//! model is calibrated to the paper's reported numbers: ≈15 min to
//! configure 27 FPGAs, >5 h to program 27 FLASH chips.

use std::sync::Arc;

use crate::network::Network;
use crate::router::MemTarget;
use crate::sim::Time;
use crate::topology::NodeId;

/// A device on the JTAG chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JtagDevice {
    ArmDap(NodeId),
    Fpga(NodeId),
}

impl Network {
    /// Devices on a card's chain, in daisy-chain order: each Zynq
    /// contributes its ARM DAP and its FPGA.
    pub fn jtag_chain(&self, card: (u32, u32, u32)) -> Vec<JtagDevice> {
        let mut v = Vec::with_capacity(54);
        for n in self.topo.card_nodes(card) {
            v.push(JtagDevice::ArmDap(n));
            v.push(JtagDevice::Fpga(n));
        }
        v
    }

    /// Configure every FPGA on `card` over JTAG with `image` (build id
    /// `build_id`). Programming is strictly sequential down the chain.
    /// Returns the total wall time.
    pub fn jtag_program_fpgas(
        &mut self,
        card: (u32, u32, u32),
        image: Arc<Vec<u8>>,
        build_id: u64,
    ) -> Time {
        let per_device =
            (image.len() as f64 * 8.0 / self.cfg.programming.jtag_fpga_bits_per_s * 1e9) as Time;
        let now = self.now();
        let mut t = now;
        for n in self.topo.card_nodes(card) {
            t += per_device;
            let st = self.node_mut(n);
            st.fpga_image = Some((build_id, image.clone()));
            st.fpga_done_at = t;
        }
        t - now
    }

    /// Program every FLASH chip on `card` over JTAG (indirect, very
    /// slow — §4.3 reports it once took more than 5 hours).
    pub fn jtag_program_flash(&mut self, card: (u32, u32, u32), image: Arc<Vec<u8>>) -> Time {
        let per_device =
            (image.len() as f64 * 8.0 / self.cfg.programming.jtag_flash_bits_per_s * 1e9) as Time;
        let now = self.now();
        let mut t = now;
        for n in self.topo.card_nodes(card) {
            t += per_device;
            let st = self.node_mut(n);
            st.flash_image = Some(image.clone());
            st.flash_done_at = t;
        }
        t - now
    }

    /// Read a word through a node's ARM DAP (debug access; bit-banged,
    /// so orders of magnitude slower than the Ring Bus).
    pub fn jtag_read(&mut self, node: NodeId, addr: u64) -> (u64, Time) {
        // One DAP transaction ≈ 100 TCK cycles at the effective rate.
        let t =
            (100.0 * 8.0 / self.cfg.programming.jtag_fpga_bits_per_s * 1e9) as Time;
        let v = self.node(node).read_addr(addr, self.now());
        (v, t)
    }

    /// Equivalent programming over the PCIe + broadcast path (§4.3): the
    /// host pushes the image once over PCIe; node (000) broadcasts it;
    /// all nodes program their FPGAs (or FLASH) in parallel. Returns the
    /// modeled wall time and applies the images. This is the fast path
    /// the paper contrasts with JTAG ("a couple of seconds, including
    /// the data transfer").
    pub fn pcie_broadcast_program(
        &mut self,
        target: MemTarget,
        image: Arc<Vec<u8>>,
        build_id: u64,
    ) -> Time {
        let p = self.cfg.programming;
        let pcie = (image.len() as f64 / p.pcie_bytes_per_s * 1e9) as Time;
        // Broadcast through the fabric: the image is chunked at the MTU;
        // the dominant term is serialization of the whole image on the
        // first link (pipelined across hops), plus the flood depth.
        let ser = (image.len() as f64 / self.cfg.link.bytes_per_ns) as Time;
        let depth = {
            let (dx, dy, dz) = self.topo.dims();
            (dx + dy + dz) as Time * self.cfg.link.hop(self.cfg.link.mtu)
        };
        let local = match target {
            MemTarget::Fpga => (image.len() as f64 / p.fpga_config_bytes_per_s * 1e9) as Time,
            MemTarget::Flash => (image.len() as f64 / p.flash_write_bytes_per_s * 1e9) as Time,
            MemTarget::Dram => 0,
        };
        let now = self.now();
        let done = now + p.host_overhead_ns + pcie + ser + depth + local;
        self.sim.advance_to(done);
        for st in &mut self.nodes {
            match target {
                MemTarget::Fpga => {
                    st.fpga_image = Some((build_id, image.clone()));
                    st.fpga_done_at = done;
                }
                MemTarget::Flash => {
                    st.flash_image = Some(image.clone());
                    st.flash_done_at = done;
                }
                MemTarget::Dram => st.dram.write_region(0, image.clone()),
            }
        }
        done - now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    #[test]
    fn chain_has_54_devices() {
        let net = Network::card();
        let chain = net.jtag_chain((0, 0, 0));
        assert_eq!(chain.len(), 54);
        assert!(matches!(chain[0], JtagDevice::ArmDap(_)));
        assert!(matches!(chain[1], JtagDevice::Fpga(_)));
    }

    #[test]
    fn jtag_fpga_programming_takes_about_15_minutes() {
        let mut net = Network::card();
        let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
        let t = net.jtag_program_fpgas((0, 0, 0), img, 1);
        let minutes = t as f64 / (60.0 * SEC as f64);
        assert!((minutes - 15.0).abs() < 1.5, "took {minutes} min, paper says ≈15");
        // Sequential: node 0 done long before node 26.
        assert!(net.nodes[0].fpga_done_at * 2 < net.nodes[26].fpga_done_at);
    }

    #[test]
    fn jtag_flash_programming_exceeds_5_hours() {
        let mut net = Network::card();
        let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
        let t = net.jtag_program_flash((0, 0, 0), img);
        assert!(t as f64 / SEC as f64 > 5.0 * 3600.0, "paper: more than 5 hours");
    }

    #[test]
    fn pcie_fpga_programming_takes_seconds_not_minutes() {
        let mut net = Network::card();
        let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
        let t = net.pcie_broadcast_program(MemTarget::Fpga, img, 2);
        let secs = t as f64 / SEC as f64;
        assert!(secs < 5.0, "PCIe path took {secs} s, paper says a couple of seconds");
        assert_eq!(net.nodes[13].fpga_image.as_ref().unwrap().0, 2);
    }

    #[test]
    fn pcie_flash_programming_takes_about_2_minutes() {
        let mut net = Network::inc3000();
        let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
        let t = net.pcie_broadcast_program(MemTarget::Flash, img, 0);
        let minutes = t as f64 / (60.0 * SEC as f64);
        // "about 2 minutes to program 1, 16, or 432" — parallel local writes.
        assert!((minutes - 2.0).abs() < 0.3, "took {minutes} min");
    }

    #[test]
    fn programming_432_over_pcie_nearly_identical_to_27() {
        let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
        let mut card = Network::card();
        let t27 = card.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
        let mut big = Network::inc3000();
        let t432 = big.pcie_broadcast_program(MemTarget::Fpga, img, 1);
        let ratio = t432 as f64 / t27 as f64;
        assert!(ratio < 1.05, "432-node programming should cost ≈ the same (ratio {ratio})");
    }
}
