//! PCIe Sandbox (§4.3): the host-side interactive utility.
//!
//! Runs on an x86 host attached over 4-lane PCIe 2.0 to node (000) of a
//! card. Simple commands read/write any address on any node ('translated'
//! underneath into Ring Bus accesses on the attached card and NetTunnel
//! accesses beyond it), retrieve the same address from all nodes
//! ('read all', via the Ring Bus), attach the UART console, dump EEPROM /
//! temperature / bitstream build ids / system configuration, load data
//! into node DRAM, broadcast kernel images and initiate boot, and
//! program FPGAs or FLASH — the preferred, fast path the paper compares
//! against JTAG.
//!
//! `PcieSandbox` keeps its own wall-clock accumulator (`elapsed`), since
//! host-side interaction is not part of the fabric's event timeline;
//! commands that need fabric traffic run the network to quiescence.

use std::sync::Arc;

use crate::network::{Network, NullApp};
use crate::node::regs;
use crate::router::MemTarget;
use crate::sim::Time;
use crate::topology::NodeId;

/// PCIe 2.0 x4 round-trip for one word access (host → (000) → host).
const PCIE_WORD_RTT: Time = 1_200;

/// The sandbox session state.
#[derive(Debug)]
pub struct PcieSandbox {
    /// Card whose node (000) the host cable is plugged into.
    pub card: (u32, u32, u32),
    /// Accumulated host-visible time spent executing commands.
    pub elapsed: Time,
    /// Node whose UART console is currently forwarded, if any.
    pub uart_attached: Option<NodeId>,
}

/// Result of one sandbox command.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CmdOutput {
    pub text: String,
    pub elapsed: Time,
}

impl PcieSandbox {
    pub fn attach(card: (u32, u32, u32)) -> Self {
        PcieSandbox { card, elapsed: 0, uart_attached: None }
    }

    fn controller(&self, net: &Network) -> NodeId {
        net.topo.controller_node(self.card)
    }

    /// Execute one textual command. Grammar (all numbers hex or decimal):
    ///
    /// ```text
    /// read <node> <addr>          write <node> <addr> <value>
    /// readall <addr>              temps | eeprom | buildids | config
    /// load <node> <addr> <len>    loadall <addr> <len>
    /// boot                        program fpga <build_id> <len>
    /// program flash <len>         uart <node> | uart detach
    /// help
    /// ```
    pub fn exec(&mut self, net: &mut Network, line: &str) -> CmdOutput {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let t0 = self.elapsed;
        let text = match toks.as_slice() {
            ["read", node, addr] => {
                let (n, a) = (parse_node(net, node), parse_num(addr));
                let v = self.read_any(net, n, a);
                format!("{} @{a:#x} = {v:#x}", n)
            }
            ["write", node, addr, value] => {
                let (n, a, v) = (parse_node(net, node), parse_num(addr), parse_num(value));
                self.write_any(net, n, a, v);
                format!("{} @{a:#x} <- {v:#x}", n)
            }
            ["readall", addr] => self.readall_fmt(net, parse_num(addr), |v| format!("{v:#x}")),
            ["temps"] => self.readall_fmt(net, regs::TEMP, |v| {
                format!("{:.1}C", v as f64 / 1000.0)
            }),
            ["eeprom"] => self.readall_fmt(net, regs::EEPROM_SERIAL, |v| format!("{v:#x}")),
            ["buildids"] => self.readall_fmt(net, regs::BUILD_ID, |v| format!("{v:#x}")),
            ["config"] => {
                let ctrl = self.controller(net);
                let (v, lat) = net.ring_read(self.card, ctrl, ctrl, regs::SYS_CARDS);
                self.elapsed += PCIE_WORD_RTT + lat;
                format!("system: {v} card(s), {} nodes", v * 27)
            }
            ["load", node, addr, len] => {
                let (n, a, l) = (parse_node(net, node), parse_num(addr), parse_num(len));
                self.load(net, Some(n), a, l as usize);
                format!("loaded {l} bytes at {a:#x} on {n}")
            }
            ["loadall", addr, len] => {
                let (a, l) = (parse_num(addr), parse_num(len));
                self.load(net, None, a, l as usize);
                format!("loaded {l} bytes at {a:#x} on all {} nodes", net.topo.node_count())
            }
            ["boot"] => {
                let ctrl = self.controller(net);
                net.tunnel_broadcast_write(ctrl, regs::BOOT_CMD, 1);
                net.run_to_quiescence(&mut NullApp);
                self.elapsed += PCIE_WORD_RTT + net.now();
                "boot initiated on all nodes".to_string()
            }
            ["program", "fpga", build_id, len] => {
                let (b, l) = (parse_num(build_id), parse_num(len));
                let img = Arc::new(vec![0u8; l as usize]);
                let t = net.pcie_broadcast_program(MemTarget::Fpga, img, b);
                self.elapsed += t;
                format!(
                    "programmed {} FPGAs (build {b:#x}) in {:.2} s",
                    net.topo.node_count(),
                    t as f64 / 1e9
                )
            }
            ["program", "flash", len] => {
                let l = parse_num(len);
                let img = Arc::new(vec![0u8; l as usize]);
                let t = net.pcie_broadcast_program(MemTarget::Flash, img, 0);
                self.elapsed += t;
                format!(
                    "programmed {} FLASH chips in {:.1} min",
                    net.topo.node_count(),
                    t as f64 / 60e9
                )
            }
            ["uart", "detach"] => {
                if let Some(n) = self.uart_attached.take() {
                    let now = net.now();
                    net.node_mut(n).write_addr(regs::UART_ATTACH, 0, now);
                }
                "uart detached".to_string()
            }
            ["uart", node] => {
                let n = parse_node(net, node);
                self.uart_attached = Some(n);
                self.write_any(net, n, regs::UART_ATTACH, 1);
                let lines = net.node(n).uart.join("\n");
                format!("uart attached to {n}\n{lines}")
            }
            ["help"] | [] => "commands: read write readall temps eeprom buildids config \
                              load loadall boot program uart help"
                .to_string(),
            other => format!("unknown command: {other:?}"),
        };
        CmdOutput { text, elapsed: self.elapsed - t0 }
    }

    /// Read any node: Ring Bus on the attached card, NetTunnel beyond.
    fn read_any(&mut self, net: &mut Network, n: NodeId, addr: u64) -> u64 {
        self.elapsed += PCIE_WORD_RTT;
        let ctrl = self.controller(net);
        if net.topo.card_of(n) == self.card {
            let (v, lat) = net.ring_read(self.card, ctrl, n, addr);
            self.elapsed += lat;
            v
        } else {
            let t0 = net.now();
            let req = net.tunnel_read(ctrl, n, addr);
            net.run_to_quiescence(&mut NullApp);
            self.elapsed += net.now() - t0;
            net.tunnel_result(req).expect("tunnel read lost")
        }
    }

    fn write_any(&mut self, net: &mut Network, n: NodeId, addr: u64, value: u64) {
        self.elapsed += PCIE_WORD_RTT;
        let ctrl = self.controller(net);
        if net.topo.card_of(n) == self.card {
            self.elapsed += net.ring_write(self.card, ctrl, n, addr, value);
        } else {
            let t0 = net.now();
            net.tunnel_write(ctrl, n, addr, value);
            net.run_to_quiescence(&mut NullApp);
            self.elapsed += net.now() - t0;
        }
    }

    fn readall_fmt(
        &mut self,
        net: &mut Network,
        addr: u64,
        fmt: impl Fn(u64) -> String,
    ) -> String {
        let ctrl = self.controller(net);
        let (vals, lat) = net.ring_read_all(self.card, ctrl, addr);
        self.elapsed += PCIE_WORD_RTT + lat;
        let mut s = String::new();
        for (n, v) in vals {
            let c = net.topo.coord(n);
            s.push_str(&format!("({}) {}\n", c.card_label(), fmt(v)));
        }
        s
    }

    /// Load `len` synthetic bytes to `node` (or broadcast to all when
    /// `None`) at `addr`: the §4.3 boot-image path. PCIe transfer +
    /// fabric traffic are both modeled.
    fn load(&mut self, net: &mut Network, node: Option<NodeId>, addr: u64, len: usize) {
        let p = net.cfg.programming;
        self.elapsed += (len as f64 / p.pcie_bytes_per_s * 1e9) as Time;
        let ctrl = self.controller(net);
        let data = Arc::new(vec![0u8; len]);
        let t0 = net.now();
        let chunk = (net.cfg.link.mtu - crate::router::HEADER_BYTES - 9) as usize;
        let mut off = 0usize;
        while off < len {
            let take = chunk.min(len - off);
            let part = Arc::new(data[off..off + take].to_vec());
            let payload = crate::router::Payload::Region {
                target: MemTarget::Dram,
                offset: addr + off as u64,
                data: part,
            };
            match node {
                Some(n) if n != ctrl => {
                    net.send_directed(ctrl, n, crate::router::Proto::Boot, payload);
                }
                Some(n) => {
                    // Local to the controller: no fabric traffic.
                    let d = match payload {
                        crate::router::Payload::Region { data, .. } => data,
                        _ => unreachable!(),
                    };
                    net.apply_region(n, MemTarget::Dram, addr + off as u64, d, net.now());
                }
                None => {
                    net.send_broadcast(ctrl, crate::router::Proto::Boot, payload);
                }
            }
            off += take;
        }
        net.run_to_quiescence(&mut NullApp);
        self.elapsed += net.now() - t0;
    }
}

fn parse_num(s: &str) -> u64 {
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).expect("bad hex number")
    } else {
        s.parse().expect("bad number")
    }
}

/// Node syntax: either a flat id (`n17` / `17`) or a Fig 1 label on the
/// attached card's coordinates (`(120)` style as `120`, 3 digits).
fn parse_node(net: &Network, s: &str) -> NodeId {
    let s = s.trim_start_matches('n');
    if s.len() == 3 && s.chars().all(|c| ('0'..='2').contains(&c)) {
        let d: Vec<u32> = s.chars().map(|c| c.to_digit(10).unwrap()).collect();
        return net.topo.id(crate::topology::Coord { x: d[0], y: d[1], z: d[2] });
    }
    NodeId(s.parse().expect("bad node id"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip_same_card() {
        let mut net = Network::card();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        let out = sb.exec(&mut net, "write 222 0xF0000100 0xBEEF");
        assert!(out.elapsed > 0);
        let out = sb.exec(&mut net, "read 222 0xF0000100");
        assert!(out.text.contains("0xbeef"), "{}", out.text);
    }

    #[test]
    fn readall_and_temps() {
        let mut net = Network::card();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        let out = sb.exec(&mut net, "temps");
        assert_eq!(out.text.lines().count(), 27);
        assert!(out.text.contains("C"));
        let out = sb.exec(&mut net, "readall 0xF0000020");
        assert!(out.text.contains("0x1bc00000"));
    }

    #[test]
    fn cross_card_access_uses_tunnel() {
        let mut net = Network::inc3000();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        // Node on a different card (card (3,3,0) controller).
        let far = net.topo.controller_node((3, 3, 0));
        let cmd = format!("write {} 0xF0000100 0x77", far.0);
        sb.exec(&mut net, &cmd);
        let out = sb.exec(&mut net, &format!("read {} 0xF0000100", far.0));
        assert!(out.text.contains("0x77"), "{}", out.text);
    }

    #[test]
    fn boot_command_boots_system() {
        let mut net = Network::card();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        sb.exec(&mut net, "loadall 0x8000 4096");
        let out = sb.exec(&mut net, "boot");
        assert!(out.text.contains("boot initiated"));
        let t = net.now() + 3 * crate::sim::SEC;
        for n in 0..27 {
            net.nodes[n].tick_boot(t);
            assert_eq!(net.nodes[n].read_addr(regs::BOOT_STATUS, t), 2);
        }
        // The kernel image actually landed in DRAM.
        assert!(net.nodes[13].dram.bytes_written >= 4096);
    }

    #[test]
    fn program_fpga_fast_path() {
        let mut net = Network::card();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        let out = sb.exec(&mut net, "program fpga 0xAB 4194304");
        assert!(out.text.contains("27 FPGAs"));
        // "a couple of seconds".
        assert!(out.elapsed < 5 * crate::sim::SEC, "{}", out.elapsed);
        let out = sb.exec(&mut net, "buildids");
        assert!(out.text.contains("0xab"));
    }

    #[test]
    fn config_reports_card_count() {
        let mut net = Network::inc3000();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        let out = sb.exec(&mut net, "config");
        assert!(out.text.contains("16 card(s)"), "{}", out.text);
        assert!(out.text.contains("432 nodes"));
    }

    #[test]
    fn unknown_command_is_reported() {
        let mut net = Network::card();
        let mut sb = PcieSandbox::attach((0, 0, 0));
        let out = sb.exec(&mut net, "frobnicate 1 2");
        assert!(out.text.contains("unknown command"));
    }
}
