//! E1 — Table 1: Bridge FIFO latency between two nodes vs hop count.
//!
//! Paper (single 27-node card): 0 hops → 0.25 µs, 1 → 1.1 µs,
//! 3 → 2.5 µs (average case), 6 → 4.7 µs (worst case).

mod common;

use inc_sim::network::{Network, NullApp};
use inc_sim::topology::Coord;

fn measure(dst: Coord) -> f64 {
    let mut net = Network::card();
    let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let d = net.topo.id(dst);
    net.fifo_connect(src, d, 0, 64);
    net.fifo_send(src, 0, &[0xBEEF]);
    net.run_to_quiescence(&mut NullApp);
    net.metrics.latency("bridge_fifo").unwrap().max() as f64 / 1000.0
}

fn main() {
    common::header("E1 / Table 1", "Bridge FIFO latency vs hops (single card)");
    let rows = [
        (0u32, 0.25f64, Coord { x: 0, y: 0, z: 0 }),
        (1, 1.1, Coord { x: 1, y: 0, z: 0 }),
        (3, 2.5, Coord { x: 1, y: 1, z: 1 }),
        (6, 4.7, Coord { x: 2, y: 2, z: 2 }),
    ];
    println!("{:<6} {:>10} {:>12} {:>8}", "hops", "paper µs", "measured µs", "err");
    let (_, wall) = common::timed(|| {
        for (hops, paper, dst) in rows {
            let got = measure(dst);
            println!(
                "{:<6} {:>10.2} {:>12.2} {:>7.1}%",
                hops,
                paper,
                got,
                common::err_pct(got, paper)
            );
        }
    });

    // Sweep every destination on the card: best/avg/worst per hop count,
    // mirroring the paper's "best, average and worst case" framing.
    println!("\nfull-card sweep (all 26 destinations from (000)):");
    let mut by_hops: std::collections::BTreeMap<u32, Vec<f64>> = Default::default();
    for z in 0..3 {
        for y in 0..3 {
            for x in 0..3 {
                if (x, y, z) == (0, 0, 0) {
                    continue;
                }
                let hops = x + y + z;
                by_hops.entry(hops).or_default().push(measure(Coord { x, y, z }));
            }
        }
    }
    println!("{:<6} {:>6} {:>10} {:>10} {:>10}", "hops", "n", "min µs", "mean µs", "max µs");
    for (hops, v) in by_hops {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let min = v.iter().cloned().fold(f64::MAX, f64::min);
        let max = v.iter().cloned().fold(0.0, f64::max);
        println!("{:<6} {:>6} {:>10.2} {:>10.2} {:>10.2}", hops, v.len(), min, mean, max);
    }
    println!("\n[bench wall time {wall:.3} s]");
}
