//! Perf harness for the simulator itself (EXPERIMENTS.md §Perf): event
//! throughput of the discrete-event core and end-to-end packet rates on
//! the three presets. This is the L3 hot path.

mod common;

use inc_sim::network::{Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::sim::Sim;
use inc_sim::topology::NodeId;
use inc_sim::util::SplitMix64;

fn main() {
    common::header("Perf", "simulator hot-path throughput");

    // Raw event queue: schedule/dispatch cycles at two steady-state
    // depths (a card's working set vs a pathological backlog).
    for depth in [10_000u64, 500_000] {
        let n = 2_000_000u64;
        let ((), secs) = common::timed(|| {
            let mut sim: Sim<u64> = Sim::new();
            let mut rng = SplitMix64::new(1);
            for i in 0..depth {
                sim.at(rng.next_u64() % 1_000_000, i);
            }
            let mut popped = 0u64;
            while let Some((t, _)) = sim.pop() {
                popped += 1;
                if popped < n {
                    // Reschedule ahead: steady-state heap churn.
                    sim.at(t + 1 + (popped % 97), popped);
                }
            }
        });
        println!(
            "event queue (depth {depth:>6}): {:.1} M events/s (schedule+dispatch)",
            n as f64 / secs / 1e6
        );
    }

    // End-to-end packet simulation rate, uniform random traffic.
    for (label, mut net, packets) in [
        ("card (27)", Network::card(), 20_000u32),
        ("inc3000 (432)", Network::inc3000(), 20_000),
    ] {
        let nn = net.topo.node_count();
        let mut rng = SplitMix64::new(7);
        let ((), secs) = common::timed(|| {
            for _ in 0..packets {
                let src = NodeId(rng.gen_range(nn) as u32);
                let mut dst = NodeId(rng.gen_range(nn) as u32);
                if dst == src {
                    dst = NodeId((dst.0 + 1) % nn as u32);
                }
                net.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
            }
            net.run_to_quiescence(&mut NullApp);
        });
        let events = net.sim.dispatched();
        println!(
            "{label:<14} {} pkts -> {} events in {:.3} s = {:.2} M events/s, {:.0} kpkt/s",
            packets,
            events,
            secs,
            events as f64 / secs / 1e6,
            packets as f64 / secs / 1e3
        );
    }

    // Broadcast storm at INC 3000 scale (the §4.3 boot path shape).
    let mut net = Network::inc3000();
    let ((), secs) = common::timed(|| {
        for i in 0..200u32 {
            net.send_broadcast(NodeId(i % 432), Proto::Raw { tag: 1 }, Payload::Synthetic(2040));
        }
        net.run_to_quiescence(&mut NullApp);
    });
    println!(
        "broadcast storm: 200 × 432-node broadcasts in {:.3} s ({:.2} M events/s)",
        secs,
        net.sim.dispatched() as f64 / secs / 1e6
    );
}
