//! Perf harness for the simulator itself (EXPERIMENTS.md §Perf): event
//! throughput of the discrete-event core and end-to-end packet rates on
//! the presets. This is the L3 hot path.
//!
//! The event-queue section benches the timing wheel against the old
//! `BinaryHeap` core (`ReferenceQueue`) on the same schedule/dispatch
//! pattern, so the speedup is printed from one binary. Alongside the
//! human-readable output, a machine-readable `BENCH_sim.json` is
//! written to the working directory so the perf trajectory can be
//! tracked across PRs.

mod common;

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::{CommMode, Message, ReliableParams};
use inc_sim::config::{SystemConfig, SystemPreset};
use inc_sim::coordinator::{Placement, RingAllreduce};
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{Fabric, Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::sim::{EventQueue, ReferenceQueue};
use inc_sim::topology::NodeId;
use inc_sim::util::SplitMix64;
use inc_sim::workload::chaos::workloads::{run_workload, ChaosWorkload, WorkloadChaosConfig};
use inc_sim::workload::chaos::{self, ChaosConfig, Scenario};
use inc_sim::workload::learners::{self, LearnerConfig, SendStrategy};
use inc_sim::workload::serving::{self, ServingConfig};
use inc_sim::workload::snn::{self, SnnConfig};

/// Numeric knob from the environment (CI's bench-smoke step shrinks the
/// run with BENCH_EVENTS / BENCH_PACKETS; defaults are the full run).
fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The two queue implementations share push/pop shapes but no trait;
/// this local one lets the bench loop be written once.
trait Queue {
    fn push(&mut self, t: u64, e: u64);
    fn pop(&mut self) -> Option<(u64, u64)>;
}

impl Queue for EventQueue<u64> {
    fn push(&mut self, t: u64, e: u64) {
        EventQueue::push(self, t, e)
    }
    fn pop(&mut self) -> Option<(u64, u64)> {
        EventQueue::pop(self)
    }
}

impl Queue for ReferenceQueue<u64> {
    fn push(&mut self, t: u64, e: u64) {
        ReferenceQueue::push(self, t, e)
    }
    fn pop(&mut self) -> Option<(u64, u64)> {
        ReferenceQueue::pop(self)
    }
}

/// Steady-state schedule/dispatch churn at a given queue depth; returns
/// events per second.
fn bench_queue<Q: Queue>(q: &mut Q, depth: u64, n: u64) -> f64 {
    let mut rng = SplitMix64::new(1);
    for i in 0..depth {
        q.push(rng.next_u64() % 1_000_000, i);
    }
    let t0 = std::time::Instant::now();
    let mut popped = 0u64;
    while let Some((t, _)) = q.pop() {
        popped += 1;
        if popped < n {
            // Reschedule ahead: steady-state churn at constant depth.
            q.push(t + 1 + (popped % 97), popped);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    // depth initial entries + one reschedule per pop while popped < n.
    assert_eq!(popped, depth + n - 1);
    n as f64 / secs
}

fn main() {
    common::header("Perf", "simulator hot-path throughput");

    let mut json = String::from("{\n  \"event_queue\": [\n");
    let mut speedup_500k = 0.0;

    // Raw event queue at two steady-state depths (a card's working set
    // vs a pathological backlog), wheel vs BinaryHeap baseline.
    let n_events = env_u64("BENCH_EVENTS", 2_000_000);
    for depth in [10_000u64, 500_000] {
        let n = n_events.max(depth);
        let wheel_eps = {
            let mut q: EventQueue<u64> = EventQueue::new();
            bench_queue(&mut q, depth, n)
        };
        let heap_eps = {
            let mut q: ReferenceQueue<u64> = ReferenceQueue::new();
            bench_queue(&mut q, depth, n)
        };
        let speedup = wheel_eps / heap_eps;
        if depth == 500_000 {
            speedup_500k = speedup;
        }
        println!(
            "event queue (depth {depth:>6}): wheel {:.1} M events/s vs heap {:.1} M events/s ({speedup:.2}x)",
            wheel_eps / 1e6,
            heap_eps / 1e6,
        );
        json.push_str(&format!(
            "    {{\"depth\": {depth}, \"impl\": \"timing_wheel\", \"events_per_sec\": {wheel_eps:.0}}},\n"
        ));
        json.push_str(&format!(
            "    {{\"depth\": {depth}, \"impl\": \"binary_heap\", \"events_per_sec\": {heap_eps:.0}}},\n"
        ));
    }
    // Trim the trailing ",\n" of the array.
    json.truncate(json.len() - 2);
    json.push_str("\n  ],\n");
    json.push_str(&format!("  \"queue_speedup_500k\": {speedup_500k:.3},\n"));
    json.push_str("  \"packets\": [\n");

    // End-to-end packet simulation rate, uniform random traffic.
    let bench_packets = env_u64("BENCH_PACKETS", 20_000) as u32;
    for (label, json_name, mut net, packets) in [
        ("card (27)", "card", Network::card(), bench_packets),
        ("inc3000 (432)", "inc3000", Network::inc3000(), bench_packets),
    ] {
        let nn = net.topo.node_count();
        let mut rng = SplitMix64::new(7);
        let ((), secs) = common::timed(|| {
            for _ in 0..packets {
                let src = NodeId(rng.gen_range(nn) as u32);
                let mut dst = NodeId(rng.gen_range(nn) as u32);
                if dst == src {
                    dst = NodeId((dst.0 + 1) % nn as u32);
                }
                net.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
            }
            net.run_to_quiescence(&mut NullApp);
        });
        let events = net.sim.dispatched();
        let eps = events as f64 / secs;
        let pps = packets as f64 / secs;
        println!(
            "{label:<14} {} pkts -> {} events in {:.3} s = {:.2} M events/s, {:.0} kpkt/s \
             (arena high-water {})",
            packets,
            events,
            secs,
            eps / 1e6,
            pps / 1e3,
            net.packets.high_water(),
        );
        json.push_str(&format!(
            "    {{\"preset\": \"{json_name}\", \"nodes\": {nn}, \"packets\": {packets}, \
             \"events_per_sec\": {eps:.0}, \"packets_per_sec\": {pps:.0}}},\n"
        ));
    }
    json.truncate(json.len() - 2);
    json.push_str("\n  ],\n");

    // Broadcast storm at INC 3000 scale (the §4.3 boot path shape).
    let storms = (bench_packets / 100).max(10);
    let mut net = Network::inc3000();
    let ((), secs) = common::timed(|| {
        for i in 0..storms {
            net.send_broadcast(NodeId(i % 432), Proto::Raw { tag: 1 }, Payload::Synthetic(2040));
        }
        net.run_to_quiescence(&mut NullApp);
    });
    let bc_eps = net.sim.dispatched() as f64 / secs;
    println!(
        "broadcast storm: {storms} × 432-node broadcasts in {:.3} s ({:.2} M events/s)",
        secs,
        bc_eps / 1e6
    );
    json.push_str(&format!(
        "  \"broadcast_storm\": {{\"broadcasts\": {storms}, \"nodes\": 432, \
         \"events_per_sec\": {bc_eps:.0}}},\n"
    ));

    // Serial vs bounded-lag sharded engine on INC 9000 (one shard per
    // cage), identical uniform traffic — the headline parallel-speedup
    // number (EXPERIMENTS.md §Perf). The sharded run must also produce
    // byte-identical metrics and final clock; checked here so a perf
    // regression can never hide a correctness one.
    let sh_packets = (2 * bench_packets).max(1000);
    let gen_pairs = |nn: u32| {
        let mut rng = SplitMix64::new(11);
        (0..sh_packets)
            .map(|_| {
                let src = rng.gen_range(nn as usize) as u32;
                let mut dst = rng.gen_range(nn as usize) as u32;
                if dst == src {
                    dst = (dst + 1) % nn;
                }
                (NodeId(src), NodeId(dst))
            })
            .collect::<Vec<_>>()
    };
    let pairs = gen_pairs(1728);
    let mut serial = Network::new(SystemConfig::inc9000());
    let ((), serial_secs) = common::timed(|| {
        for &(s, d) in &pairs {
            serial.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
        }
        serial.run_to_quiescence(&mut NullApp);
    });
    let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    let ((), sharded_secs) = common::timed(|| {
        for &(s, d) in &pairs {
            sharded.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
        }
        sharded.run_to_quiescence();
    });
    let matches = serial.metrics.fabric_view() == sharded.metrics().fabric_view()
        && serial.now() == sharded.now();
    let serial_pps = sh_packets as f64 / serial_secs;
    let sharded_pps = sh_packets as f64 / sharded_secs;
    let speedup = serial_secs / sharded_secs;
    println!(
        "inc9000 (1728)  {sh_packets} pkts: serial {:.0} kpkt/s vs sharded×{} {:.0} kpkt/s \
         ({speedup:.2}x, {} workers, metrics+clock match: {matches})",
        serial_pps / 1e3,
        sharded.shard_count(),
        sharded_pps / 1e3,
        sharded.worker_count(),
    );
    json.push_str(&format!(
        "  \"inc9000_sharded\": {{\"packets\": {sh_packets}, \
         \"serial_packets_per_sec\": {serial_pps:.0}, \
         \"sharded_packets_per_sec\": {sharded_pps:.0}, \
         \"shards\": {}, \"workers\": {}, \"speedup\": {speedup:.3}, \
         \"matches_serial\": {matches}}},\n",
        sharded.shard_count(),
        sharded.worker_count(),
    ));

    // Shard-local state domains + distance-aware multi-shard epoch
    // batching (EXPERIMENTS.md E12): the per-shard memory cut from the
    // owned-subset domains, and how many windows (on how many shards
    // simultaneously) the distance-aware batching coalesces on sparse
    // staggered traffic. Tracked across PRs so neither the memory cut
    // nor the batching win can silently regress.
    let serial_state = Network::new(SystemConfig::inc9000()).state_bytes();
    let mut dnet = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    let per_shard = dnet.state_bytes_per_shard();
    let shard_state_max = *per_shard.iter().max().unwrap();
    // The remap bookkeeping itself (O(mesh) index maps, replicated per
    // shard) — reported alongside so the cut is never overstated; it is
    // far below the dynamic state it makes partitionable.
    let index_map_bytes: u64 =
        dnet.shards().iter().map(|s| s.domain.index_bytes()).max().unwrap();
    assert_eq!(per_shard.iter().sum::<u64>(), serial_state, "state not conserved");
    assert!(
        index_map_bytes * 4 < shard_state_max,
        "index maps ({index_map_bytes} B) should be far below the per-shard state"
    );
    {
        // Sparse staggered traffic: bursts local to cages 0 and 3 in
        // disjoint time phases — both owning shards must sprint.
        let pm = CommMode::Postmaster { queue: 0 };
        let pairs = [(NodeId(0), NodeId(1)), (NodeId(1726), NodeId(1727))];
        let eps: Vec<_> = pairs
            .iter()
            .flat_map(|&(a, b)| [a, b])
            .map(|n| dnet.open(n, pm))
            .collect();
        for phase in 0..6u64 {
            let (ep, dst) = if phase % 2 == 0 { (&eps[0], pairs[0].1) } else { (&eps[2], pairs[1].1) };
            for i in 0..4u64 {
                dnet.send_at(
                    phase * 250_000 + i * 2_000,
                    ep,
                    dst,
                    Message::new(vec![i as u8; 64]),
                );
            }
        }
        // Plus one phase with *both* cages active at the same instants:
        // the cage-0/cage-3 horizon is 3 hops × 684 ns, so both shards
        // sprint within the same epochs (simultaneous, not alternating).
        for i in 0..4u64 {
            dnet.send_at(1_500_000 + i * 2_000, &eps[0], pairs[0].1, Message::new(vec![7; 64]));
            dnet.send_at(1_500_000 + i * 2_000, &eps[2], pairs[1].1, Message::new(vec![7; 64]));
        }
        dnet.run_to_quiescence();
    }
    let windows_merged = dnet.metrics().windows_merged;
    let merging_shards =
        dnet.shards().iter().filter(|s| s.metrics.windows_merged > 0).count();
    let state_cut = serial_state as f64 / shard_state_max as f64;
    println!(
        "inc9000 domains serial state {:.2} MB vs {:.2} MB/shard ({state_cut:.2}x cut); \
         sparse batching merged {windows_merged} windows on {merging_shards} shards",
        serial_state as f64 / 1e6,
        shard_state_max as f64 / 1e6,
    );
    json.push_str(&format!(
        "  \"inc9000_domain\": {{\"serial_state_bytes\": {serial_state}, \
         \"shard_state_bytes_max\": {shard_state_max}, \
         \"shard_index_map_bytes\": {index_map_bytes}, \"shards\": {}, \
         \"state_cut\": {state_cut:.3}, \"windows_merged\": {windows_merged}, \
         \"merging_shards\": {merging_shards}}},\n",
        dnet.shard_count(),
    ));
    assert!(merging_shards >= 2, "multi-shard batching failed to fire");

    // App workloads through the engine-agnostic Fabric trait on INC
    // 9000: distributed learners (Postmaster streams, grid strided
    // across cages) and the ring all-reduce (ranks scattered across
    // cages), serial vs sharded. The bench asserts the *app-level
    // results* match, so the parallel engine can never quietly change a
    // workload's answer.
    let steps = (bench_packets / 2_000).clamp(1, 8);
    let lcfg = LearnerConfig {
        learners: 64,
        outputs_per_step: 8,
        record_bytes: 64,
        compute_ns: 40_000,
        steps,
        stride: 27, // spread the grid across all four cages
        ..LearnerConfig::default()
    };
    let (l_serial, l_serial_secs) = common::timed(|| {
        let mut net = Network::new(SystemConfig::inc9000());
        learners::run(&mut net, lcfg, SendStrategy::Streamed)
    });
    let (l_sharded, l_sharded_secs) = common::timed(|| {
        let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        learners::run(&mut net, lcfg, SendStrategy::Streamed)
    });
    let learners_match = l_serial == l_sharded;
    let learners_speedup = l_serial_secs / l_sharded_secs;

    let ar_bytes = 512 * 1024;
    let (ar_serial, ar_serial_secs) = common::timed(|| {
        let mut net = Network::new(SystemConfig::inc9000());
        let ranks = Placement::Scattered.select(&net.topo, 8);
        RingAllreduce::new(&mut net, ranks, ar_bytes).run(&mut net)
    });
    let (ar_sharded, ar_sharded_secs) = common::timed(|| {
        let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        let ranks = Placement::Scattered.select(net.topo(), 8);
        RingAllreduce::new(&mut net, ranks, ar_bytes).run(&mut net)
    });
    let allreduce_match = ar_serial == ar_sharded;
    let allreduce_speedup = ar_serial_secs / ar_sharded_secs;
    let app_matches = learners_match && allreduce_match;
    let app_speedup = (l_serial_secs + ar_serial_secs) / (l_sharded_secs + ar_sharded_secs);
    println!(
        "inc9000 apps    learners {learners_speedup:.2}x, all-reduce {allreduce_speedup:.2}x \
         (combined {app_speedup:.2}x, app results match: {app_matches})"
    );
    json.push_str(&format!(
        "  \"inc9000_app_sharded\": {{\"learners_speedup\": {learners_speedup:.3}, \
         \"allreduce_speedup\": {allreduce_speedup:.3}, \"speedup\": {app_speedup:.3}, \
         \"matches_serial\": {app_matches}}},\n"
    ));

    // Comm-mode sweep (EXPERIMENTS.md E11): identical small-message
    // traffic through one generic function, the virtual channel as the
    // only variable — the Table-1-style latency comparison plus the
    // simulator's wall-clock message rate per mode.
    let sweep_msgs = ((bench_packets / 4).max(500)) as u64;
    json.push_str("  \"comm_mode_sweep\": [\n");
    println!("comm-mode sweep: {sweep_msgs} x 64 B messages, 32 endpoints on inc3000");
    for (cli, mode, hist) in [
        ("fifo", CommMode::BridgeFifo { width_bits: 64 }, "bridge_fifo"),
        ("pm", CommMode::Postmaster { queue: 0 }, "postmaster"),
        ("eth", CommMode::Ethernet { rx: RxMode::Interrupt }, "eth_frame"),
    ] {
        let mut net = Network::inc3000();
        let nn = net.topo.node_count() as u32;
        let k = 32u32;
        let nodes: Vec<NodeId> = (0..k).map(|i| NodeId(i * (nn / k))).collect();
        let eps: Vec<_> = nodes.iter().map(|&n| net.open(n, mode)).collect();
        if net.caps(mode).pair_setup {
            for (i, ep) in eps.iter().enumerate() {
                for (j, &dst) in nodes.iter().enumerate() {
                    if i != j {
                        net.connect(ep, dst);
                    }
                }
            }
        }
        let mut rng = SplitMix64::new(13);
        let ((), secs) = common::timed(|| {
            for m in 0..sweep_msgs {
                let i = rng.gen_range(k as usize);
                let mut j = rng.gen_range(k as usize);
                if j == i {
                    j = (j + 1) % k as usize;
                }
                net.send(&eps[i], nodes[j], Message::new(vec![m as u8; 64]));
            }
            net.run_to_quiescence(&mut NullApp);
        });
        let mean_ns = net.metrics.latency(hist).map(|h| h.mean()).unwrap_or(0.0);
        let t = net.metrics.mode_traffic[mode.name()];
        assert_eq!(t.messages, sweep_msgs, "sweep lost {} messages", mode.name());
        let mps = sweep_msgs as f64 / secs;
        println!(
            "  {cli:<5} mean latency {:>9.2} µs, {:>8.0} msgs/s wall-clock",
            mean_ns / 1000.0,
            mps
        );
        json.push_str(&format!(
            "    {{\"mode\": \"{cli}\", \"messages\": {sweep_msgs}, \
             \"mean_latency_ns\": {mean_ns:.0}, \"msgs_per_sec\": {mps:.0}}},\n"
        ));
    }
    json.truncate(json.len() - 2);
    json.push_str("\n  ],\n");

    // Chaos storm under SLOs (EXPERIMENTS.md E13): a seeded correlated
    // link-failure storm on inc3000 with background Postmaster traffic,
    // serial vs per-card sharded — delivered throughput and p99 latency
    // *while links fail and heal*, plus the wall-clock cost of running
    // the chaos harness on each engine. Byte-identity of the graded SLO
    // report is asserted, same contract as the traffic sections.
    let ccfg = ChaosConfig::new(Scenario::Storm, 42);
    let chaos_sys = || {
        let mut sys = SystemConfig::inc3000();
        sys.rx_capacity = ccfg.suggested_rx_capacity();
        sys
    };
    let (chaos_serial, chaos_serial_secs) = common::timed(|| {
        let mut net = Network::new(chaos_sys());
        chaos::run(&mut net, &ccfg, 1)
    });
    let (chaos_sharded, chaos_sharded_secs) = common::timed(|| {
        let mut net = ShardedNetwork::new(chaos_sys(), 16);
        let k = net.shard_count() as u32;
        chaos::run(&mut net, &ccfg, k)
    });
    let chaos_match = {
        let mut sh = chaos_sharded.clone();
        sh.shards = chaos_serial.shards;
        chaos_serial == sh
    };
    println!(
        "chaos storm    {:.0} msg/s virtual under failures, p99 {} ns, \
         convergence {} ns (serial {:.3} s, sharded {:.3} s, reports match: {chaos_match})",
        chaos_serial.throughput_msgs_per_s(),
        chaos_serial.p99_ns,
        chaos_serial.convergence_ns,
        chaos_serial_secs,
        chaos_sharded_secs,
    );
    json.push_str(&format!(
        "  \"chaos\": {{\"scenario\": \"storm\", \"seed\": {}, \
         \"delivered\": {}, \"sent\": {}, \
         \"delivered_msgs_per_s_virtual\": {:.1}, \"p50_ns\": {}, \"p99_ns\": {}, \
         \"convergence_ns\": {}, \"dropped\": {}, \"stalled_ns\": {}, \
         \"slo_pass\": {}, \"serial_secs\": {chaos_serial_secs:.4}, \
         \"sharded_secs\": {chaos_sharded_secs:.4}, \"matches_serial\": {chaos_match}}},\n",
        chaos_serial.seed,
        chaos_serial.delivered,
        chaos_serial.sent,
        chaos_serial.throughput_msgs_per_s(),
        chaos_serial.p50_ns,
        chaos_serial.p99_ns,
        chaos_serial.convergence_ns,
        chaos_serial.dropped,
        chaos_serial.stalled_ns,
        chaos_serial.passed(),
    ));

    // Open-loop inference serving (EXPERIMENTS.md E15): external
    // clients reach the mesh through the gateway NAT at a configured
    // offered rate; frontends fan requests out to workers. Per preset:
    // p50/p99/p999 latency and sustained throughput, serial vs sharded
    // with the serving reports asserted byte-identical. Inc27000 runs
    // at 64 shards — far beyond any host's core count, i.e. the epoch
    // work-stealing regime — and can be shrunk or skipped in CI via
    // BENCH_MEGA_REQUESTS (0 skips the mega preset entirely).
    let serve_requests = env_u64("BENCH_SERVE_REQUESTS", 400);
    let mega_requests = env_u64("BENCH_MEGA_REQUESTS", 200);
    let mut serving_match = true;
    json.push_str("  \"serving\": [\n");
    for (name, preset, shards, requests, stride, rate) in [
        ("card", SystemPreset::Card, 1u32, serve_requests, 1usize, 50_000.0),
        ("inc3000", SystemPreset::Inc3000, 16, serve_requests, 19, 100_000.0),
        ("inc27000", SystemPreset::Inc27000, 64, mega_requests, 997, 100_000.0),
    ] {
        if requests == 0 {
            println!("serving {name:<9} skipped (requests knob set to 0)");
            continue;
        }
        let cfg = ServingConfig { requests, rate_per_s: rate, stride, ..ServingConfig::default() };
        let (rep, serial_secs) = common::timed(|| {
            let mut net = Network::new(SystemConfig::new(preset));
            serving::run(&mut net, cfg)
        });
        let (matches, sharded_secs) = if shards > 1 {
            let (srep, secs) = common::timed(|| {
                let mut net = ShardedNetwork::new(SystemConfig::new(preset), shards);
                serving::run(&mut net, cfg)
            });
            (srep == rep, secs)
        } else {
            (true, serial_secs)
        };
        serving_match &= matches;
        println!(
            "serving {name:<9} {requests} reqs @ {rate:.0}/s: p50 {:.1} µs, p99 {:.1} µs, \
             p999 {:.1} µs, {:.0} req/s sustained (serial {serial_secs:.3} s, \
             sharded×{shards} {sharded_secs:.3} s, match: {matches})",
            rep.p50_ns as f64 / 1e3,
            rep.p99_ns as f64 / 1e3,
            rep.p999_ns as f64 / 1e3,
            rep.throughput_rps,
        );
        json.push_str(&format!(
            "    {{\"preset\": \"{name}\", \"shards\": {shards}, \"requests\": {requests}, \
             \"offered_rps\": {:.0}, \"p50_ns\": {}, \"p99_ns\": {}, \"p999_ns\": {}, \
             \"throughput_rps\": {:.0}, \"serial_secs\": {serial_secs:.4}, \
             \"sharded_secs\": {sharded_secs:.4}, \"matches_serial\": {matches}}},\n",
            rep.offered_rps, rep.p50_ns, rep.p99_ns, rep.p999_ns, rep.throughput_rps,
        ));
    }
    json.truncate(json.len() - 2);
    json.push_str("\n  ],\n");

    // Saturation sweep on the card (E15 protocol): offered rate swept
    // across ~an order of magnitude; the reported saturation point is
    // the highest sustained throughput.
    let sweep_rates = [25_000.0, 50_000.0, 100_000.0, 200_000.0];
    let sat_cfg =
        ServingConfig { requests: serve_requests.min(200).max(1), ..ServingConfig::default() };
    let (sat_rps, sat_reps) = serving::saturation_sweep(Network::card, sat_cfg, &sweep_rates);
    println!(
        "serving saturation (card): {sat_rps:.0} req/s across offered {:?} req/s",
        sweep_rates.map(|r| r as u64),
    );
    json.push_str(&format!(
        "  \"serving_saturation\": {{\"preset\": \"card\", \"requests\": {}, \
         \"rates\": [{}], \"throughput_rps\": [{}], \"saturation_rps\": {sat_rps:.0}}},\n",
        sat_cfg.requests,
        sweep_rates.map(|r| format!("{r:.0}")).join(", "),
        sat_reps.iter().map(|r| format!("{:.0}", r.throughput_rps)).collect::<Vec<_>>().join(", "),
    ));

    // O(owned) acceptance on the mega mesh: with 27 648 nodes split 64
    // ways, each shard's global→local index maps must scale with the
    // ~432-node owned subset — not the global mesh, which is what the
    // old dense Vec remap tables cost on every shard.
    let (mega_index_bytes, mega_owned_bound) = {
        let mnet = ShardedNetwork::new(SystemConfig::new(SystemPreset::Inc27000), 64);
        let worst = mnet
            .shards()
            .iter()
            .map(|s| (s.domain.index_bytes(), s.domain.node_count(), s.domain.link_count()))
            .max()
            .unwrap();
        (worst.0, 64 * (worst.1 + worst.2) as u64 + 4096)
    };
    println!(
        "inc27000 domains: worst shard index maps {:.1} KB (O(owned) bound {:.1} KB, 64 shards)",
        mega_index_bytes as f64 / 1e3,
        mega_owned_bound as f64 / 1e3,
    );
    json.push_str(&format!(
        "  \"inc27000_domain\": {{\"shards\": 64, \
         \"shard_index_map_bytes\": {mega_index_bytes}, \
         \"owned_bound_bytes\": {mega_owned_bound}}},\n"
    ));

    // Reliable-transport overhead (EXPERIMENTS.md §Reliable transport,
    // E14 acceptance): the same ring all-reduce raw vs over the
    // ack/retransmit transport on a healthy fabric — framing + ack cost
    // with zero retransmits — then under the drop scenario's scripted
    // node death, where the loss is real and the retransmit/liveness
    // machinery has to pay its way.
    let rel_bytes = 256 * 1024u64;
    let pm = CommMode::Postmaster { queue: 0 };
    let raw_stats = {
        let mut net = Network::card();
        let ranks = Placement::Scattered.select(&net.topo, 8);
        RingAllreduce::with_mode(&mut net, ranks, rel_bytes, pm).run(&mut net)
    };
    let (rel_stats, rel_acks, rel_rtx) = {
        let mut net = Network::card();
        let ranks = Placement::Scattered.select(&net.topo, 8);
        let stats = RingAllreduce::with_mode_reliable(
            &mut net,
            ranks,
            rel_bytes,
            pm,
            ReliableParams::default(),
            0,
        )
        .run(&mut net);
        (stats, net.metrics.acks, net.metrics.retransmits)
    };
    let rel_overhead = rel_stats.makespan as f64 / raw_stats.makespan as f64;
    let drop_cfg = WorkloadChaosConfig::new(ChaosWorkload::Allreduce, Scenario::Drop, 42);
    let (drop_report, drop_secs) = common::timed(|| {
        let mut net = Network::new(drop_cfg.system_config());
        run_workload(&mut net, &drop_cfg, 1)
    });
    println!(
        "reliable xfer  all-reduce {rel_bytes} B: {rel_overhead:.2}x makespan at 0% loss \
         ({} vs {} µs, {rel_acks} acks, {rel_rtx} retransmits); under drop: \
         {} retransmits, {} death(s) detected, passed: {}",
        rel_stats.makespan / 1000,
        raw_stats.makespan / 1000,
        drop_report.retransmits,
        drop_report.peers_declared_down,
        drop_report.passed(),
    );
    json.push_str(&format!(
        "  \"reliable\": {{\"allreduce_bytes\": {rel_bytes}, \
         \"raw_makespan_ns\": {}, \"reliable_makespan_ns\": {}, \
         \"overhead\": {rel_overhead:.3}, \"acks\": {rel_acks}, \
         \"retransmits_no_loss\": {rel_rtx}, \"drop_retransmits\": {}, \
         \"drop_peers_declared_down\": {}, \"drop_elapsed_ns\": {}, \
         \"drop_secs\": {drop_secs:.4}, \"drop_passed\": {}}},\n",
        raw_stats.makespan,
        rel_stats.makespan,
        drop_report.retransmits,
        drop_report.peers_declared_down,
        drop_report.elapsed_ns,
        drop_report.passed(),
    ));

    // Spiking workload (EXPERIMENTS.md E16): the event-per-spike traffic
    // class the INC was built for — LIF ticks, multicast spike fan-out,
    // per-synapse delay timers. Virtual spikes/s plus the simulator's
    // wall-clock event rate on this event-dense pattern, serial vs 16
    // shards with the normalized reports asserted byte-identical
    // (wheel_peak / events_dispatched are per-shard by construction).
    // CI shrinks via BENCH_SNN_TICKS / BENCH_SNN_NODES; 0 ticks skips.
    let snn_ticks = env_u64("BENCH_SNN_TICKS", 60) as u32;
    let snn_nodes = env_u64("BENCH_SNN_NODES", 48) as usize;
    let mut snn_match = true;
    if snn_ticks == 0 {
        println!("snn            skipped (BENCH_SNN_TICKS=0)");
        json.push_str("  \"snn\": null,\n");
    } else {
        let snn_cfg = SnnConfig {
            nodes: snn_nodes,
            neurons_per_node: env_u64("BENCH_SNN_NEURONS", 24) as u32,
            ticks: snn_ticks,
            rate_ppm: env_u64("BENCH_SNN_RATE", 150_000),
            // Widest stride that still leaves the population (plus the
            // excluded gateway) strided candidates on the 432-node mesh.
            stride: (SystemPreset::Inc3000.node_count() as usize / (snn_nodes + 2)).max(1),
            ..SnnConfig::default()
        };
        let (snn_rep, snn_serial_secs) = common::timed(|| {
            let mut net = Network::new(SystemConfig::new(SystemPreset::Inc3000));
            snn::run(&mut net, snn_cfg)
        });
        let (snn_srep, snn_sharded_secs) = common::timed(|| {
            let mut net = ShardedNetwork::new(SystemConfig::new(SystemPreset::Inc3000), 16);
            snn::run(&mut net, snn_cfg)
        });
        snn_match = snn_srep.normalized() == snn_rep.normalized();
        let snn_events_per_s = snn_rep.events_dispatched as f64 / snn_serial_secs.max(1e-9);
        println!(
            "snn inc3000    {} nodes × {} neurons × {} ticks: {} spikes \
             ({:.0} virtual spikes/s), {} deliveries, {:.2}M events/s wall \
             (serial {snn_serial_secs:.3} s, sharded×16 {snn_sharded_secs:.3} s, \
             match: {snn_match})",
            snn_rep.nodes,
            snn_rep.neurons,
            snn_rep.ticks,
            snn_rep.spikes_emitted,
            snn_rep.spikes_per_s,
            snn_rep.spikes_delivered,
            snn_events_per_s / 1e6,
        );
        json.push_str(&format!(
            "  \"snn\": {{\"preset\": \"inc3000\", \"shards\": 16, \"nodes\": {}, \
             \"neurons_per_node\": {}, \"ticks\": {}, \"spikes_emitted\": {}, \
             \"spikes_delivered\": {}, \"spikes_per_s\": {:.0}, \
             \"events_dispatched\": {}, \"events_per_s_wall\": {snn_events_per_s:.0}, \
             \"serial_secs\": {snn_serial_secs:.4}, \
             \"sharded_secs\": {snn_sharded_secs:.4}, \
             \"matches_serial\": {snn_match}}},\n",
            snn_rep.nodes,
            snn_rep.neurons,
            snn_rep.ticks,
            snn_rep.spikes_emitted,
            snn_rep.spikes_delivered,
            snn_rep.spikes_per_s,
            snn_rep.events_dispatched,
        ));
    }
    // Dense-traffic optimistic showdown (EXPERIMENTS.md E17): the
    // speculative (Time Warp) runner vs the conservative bounded-lag
    // engine on two dense Inc9000 patterns — the hotspot chaos scenario
    // (background senders converging on one region while links fail)
    // and the spiking workload's multicast fan-out. Reported per
    // pattern: conservative vs optimistic wall clock and speedup, the
    // conservative engine's merged windows, the optimistic engine's
    // rollbacks / replayed events / checkpoint bytes. Byte-identity of
    // *both* engines against the serial oracle is hard-asserted below —
    // a perf win that changes the answer is a bug, not a result.
    let mut dense_match = true;
    json.push_str("  \"dense_traffic\": [\n");
    {
        let hcfg = ChaosConfig::new(Scenario::Hotspot, 5);
        let hsys = || {
            let mut sys = SystemConfig::inc9000();
            sys.rx_capacity = hcfg.suggested_rx_capacity();
            sys
        };
        let serial_rep = {
            let mut net = Network::new(hsys());
            chaos::run(&mut net, &hcfg, 1)
        };
        let (cons_rep, cons_secs, cons_merged) = {
            let mut net = ShardedNetwork::new(hsys(), 4);
            let k = net.shard_count() as u32;
            let (rep, secs) = common::timed(|| chaos::run(&mut net, &hcfg, k));
            (rep, secs, net.metrics().windows_merged)
        };
        let (opt_rep, opt_secs, opt_m) = {
            let mut net = ShardedNetwork::new(hsys(), 4);
            net.set_optimistic(true);
            let k = net.shard_count() as u32;
            let (rep, secs) = common::timed(|| chaos::run(&mut net, &hcfg, k));
            (rep, secs, net.metrics())
        };
        let matches = {
            // The shard count on the report is presentation metadata.
            let mut c = cons_rep.clone();
            c.shards = serial_rep.shards;
            let mut o = opt_rep;
            o.shards = serial_rep.shards;
            c == serial_rep && o == serial_rep
        };
        dense_match &= matches;
        let speedup = cons_secs / opt_secs;
        println!(
            "dense hotspot  inc9000×4: conservative {cons_secs:.3} s vs optimistic \
             {opt_secs:.3} s ({speedup:.2}x); {cons_merged} windows merged vs \
             {} rollbacks / {} replayed / {:.1} KB ckpts (match: {matches})",
            opt_m.rollbacks,
            opt_m.events_replayed,
            opt_m.checkpoints_bytes as f64 / 1e3,
        );
        json.push_str(&format!(
            "    {{\"pattern\": \"hotspot\", \"preset\": \"inc9000\", \"shards\": 4, \
             \"conservative_secs\": {cons_secs:.4}, \"optimistic_secs\": {opt_secs:.4}, \
             \"speedup\": {speedup:.3}, \"windows_merged\": {cons_merged}, \
             \"rollbacks\": {}, \"events_replayed\": {}, \"checkpoints_bytes\": {}, \
             \"matches_serial\": {matches}}},\n",
            opt_m.rollbacks, opt_m.events_replayed, opt_m.checkpoints_bytes,
        ));
    }
    {
        // Spike multicast strided across all four cages: every tick
        // fans spikes out through the spanning-tree router, so boundary
        // traffic is continuous and the speculative engine earns (or
        // pays for) its checkpoints on the densest pattern we have.
        let dcfg = SnnConfig {
            nodes: 32,
            neurons_per_node: 12,
            ticks: env_u64("BENCH_DENSE_SNN_TICKS", 24) as u32,
            rate_ppm: 200_000,
            stride: 53,
            ..SnnConfig::default()
        };
        let serial_rep = {
            let mut net = Network::new(SystemConfig::inc9000());
            snn::run(&mut net, dcfg)
        };
        let (cons_rep, cons_secs, cons_merged) = {
            let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
            let (rep, secs) = common::timed(|| snn::run(&mut net, dcfg));
            (rep, secs, net.metrics().windows_merged)
        };
        let (opt_rep, opt_secs, opt_m) = {
            let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
            net.set_optimistic(true);
            let (rep, secs) = common::timed(|| snn::run(&mut net, dcfg));
            (rep, secs, net.metrics())
        };
        let matches = cons_rep.normalized() == serial_rep.normalized()
            && opt_rep.normalized() == serial_rep.normalized();
        dense_match &= matches;
        let speedup = cons_secs / opt_secs;
        println!(
            "dense snn      inc9000×4: conservative {cons_secs:.3} s vs optimistic \
             {opt_secs:.3} s ({speedup:.2}x); {cons_merged} windows merged vs \
             {} rollbacks / {} replayed / {:.1} KB ckpts (match: {matches})",
            opt_m.rollbacks,
            opt_m.events_replayed,
            opt_m.checkpoints_bytes as f64 / 1e3,
        );
        json.push_str(&format!(
            "    {{\"pattern\": \"snn_multicast\", \"preset\": \"inc9000\", \"shards\": 4, \
             \"conservative_secs\": {cons_secs:.4}, \"optimistic_secs\": {opt_secs:.4}, \
             \"speedup\": {speedup:.3}, \"windows_merged\": {cons_merged}, \
             \"rollbacks\": {}, \"events_replayed\": {}, \"checkpoints_bytes\": {}, \
             \"matches_serial\": {matches}}}\n",
            opt_m.rollbacks, opt_m.events_replayed, opt_m.checkpoints_bytes,
        ));
    }
    json.push_str("  ]\n");
    json.push_str("}\n");

    std::fs::write("BENCH_sim.json", &json).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
    assert!(matches, "sharded run diverged from the serial oracle");
    assert!(app_matches, "sharded app workload diverged from the serial oracle");
    assert!(serving_match, "sharded serving report diverged from the serial oracle");
    assert!(
        mega_index_bytes <= mega_owned_bound,
        "inc27000 per-shard index maps are not O(owned): {mega_index_bytes} B > \
         bound {mega_owned_bound} B"
    );
    assert!(chaos_match, "chaos SLO report diverged across engines");
    assert!(chaos_serial.passed(), "chaos storm violated SLOs: {:?}", chaos_serial.violations());
    assert!(snn_match, "sharded snn report diverged from the serial oracle");
    assert!(dense_match, "dense-traffic optimistic run diverged from the serial oracle");
    assert_eq!(rel_rtx, 0, "reliable all-reduce retransmitted on a healthy fabric");
    assert!(rel_acks > 0, "reliable all-reduce produced no acks");
    assert!(drop_report.retransmits > 0, "drop scenario forced no retransmission");
    assert!(drop_report.passed(), "reliable all-reduce under drop: {:?}", drop_report.violations());
}
