//! Shared mini-bench harness (offline environment: no criterion). Each
//! bench binary prints the paper's rows next to the measured ones and a
//! wall-clock timing of the simulation itself.

use std::time::Instant;

/// Run `f` once, returning (result, wall seconds).
#[allow(dead_code)]
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Print a standard bench header.
#[allow(dead_code)]
pub fn header(exp: &str, title: &str) {
    println!("==============================================================");
    println!("{exp}: {title}");
    println!("==============================================================");
}

/// Relative error in percent.
#[allow(dead_code)]
pub fn err_pct(measured: f64, paper: f64) -> f64 {
    (measured - paper) / paper * 100.0
}
