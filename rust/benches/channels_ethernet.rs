//! E4 — Fig 3: Internal Ethernet operation. Frame latency breakdown,
//! message throughput, and the interrupt-vs-polling receive comparison
//! the paper calls out ("far more efficient under high traffic").

mod common;

use inc_sim::channels::ethernet::RxMode;
use inc_sim::network::{Network, NullApp};
use inc_sim::topology::{Coord, NodeId};

fn main() {
    common::header("E4 / Fig 3", "Internal (virtual) Ethernet");

    // Frame latency vs hop distance.
    println!("single 1400 B frame latency (includes kernel stack + driver + DMA):");
    for (label, dst) in [
        ("1 hop", Coord { x: 1, y: 0, z: 0 }),
        ("3 hops", Coord { x: 1, y: 1, z: 1 }),
        ("6 hops", Coord { x: 2, y: 2, z: 2 }),
    ] {
        let mut net = Network::card();
        let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let b = net.topo.id(dst);
        net.eth_send(a, b, 1400, 0);
        net.run_to_quiescence(&mut NullApp);
        let lat = net.metrics.packet_latency["eth_frame"].max();
        println!("  {label:<8} {:.1} µs", lat as f64 / 1000.0);
    }

    // Bulk message throughput node-to-node (TCP-like segmentation).
    let ((), wall) = common::timed(|| {
        let mut net = Network::card();
        let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let b = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        let bytes = 10 * 1024 * 1024u64;
        net.eth_send_message(a, b, bytes, 1);
        net.run_to_quiescence(&mut NullApp);
        let secs = net.now() as f64 / 1e9;
        println!(
            "\n10 MiB transfer: {:.1} MB/s goodput ({} frames; link line rate 1 GB/s — \
             the software path is the bottleneck, which is the paper's point)",
            bytes as f64 / secs / 1e6,
            net.eth.port(b).frames_rx
        );
    });

    // IRQ vs polling: receiver CPU time under rising load.
    println!("\nreceive-side CPU time, 26 senders × N frames each:");
    println!(
        "{:>6} {:>14} {:>14} {:>12} {:>8}",
        "N", "irq cpu ms", "poll cpu ms", "poll saves", "irqs"
    );
    for n in [1u32, 4, 16, 64] {
        let run = |mode: RxMode| {
            let mut net = Network::card();
            let dst = net.topo.id(Coord { x: 1, y: 1, z: 1 });
            net.eth_set_mode(dst, mode);
            for i in 0..27u32 {
                let src = NodeId(i);
                if src != dst {
                    for _ in 0..n {
                        net.eth_send(src, dst, 1400, 0);
                    }
                }
            }
            net.run_to_quiescence(&mut NullApp);
            (net.nodes[dst.0 as usize].cpu_busy_ns, net.eth.port(dst).irqs_taken)
        };
        let (irq_cpu, irqs) = run(RxMode::Interrupt);
        let (poll_cpu, _) = run(RxMode::Polling { interval: 20_000 });
        println!(
            "{:>6} {:>14.3} {:>14.3} {:>11.1}% {:>8}",
            n,
            irq_cpu as f64 / 1e6,
            poll_cpu as f64 / 1e6,
            (1.0 - poll_cpu as f64 / irq_cpu as f64) * 100.0,
            irqs
        );
    }
    println!("\n[bench wall time {wall:.3} s]");
}
