//! E8 — §3.2 claim: Postmaster's send-as-generated pattern overlaps
//! computation and communication for distributed learners, vs
//! aggregate-then-send. Sweeps output count, record size and compute
//! window; the advantage should grow as communication grows relative to
//! compute.

mod common;

use inc_sim::network::Network;
use inc_sim::workload::learners::{overlap_advantage, LearnerConfig};

fn main() {
    common::header("E8 / §3.2", "compute/communication overlap for distributed learners");
    println!(
        "{:>8} {:>8} {:>12} {:>14} {:>14} {:>10}",
        "outputs", "bytes", "compute µs", "streamed µs", "aggregated µs", "advantage"
    );
    let ((), wall) = common::timed(|| {
        for outputs in [4usize, 16, 64] {
            for bytes in [32usize, 256] {
                for compute_us in [20u64, 50, 200] {
                    let cfg = LearnerConfig {
                        learners: 27,
                        outputs_per_step: outputs,
                        record_bytes: bytes,
                        compute_ns: compute_us * 1000,
                        steps: 3,
                        ..LearnerConfig::default()
                    };
                    let (s, a) = overlap_advantage(Network::card, cfg);
                    println!(
                        "{:>8} {:>8} {:>12} {:>14.1} {:>14.1} {:>9.2}x",
                        outputs,
                        bytes,
                        compute_us,
                        s / 1000.0,
                        a / 1000.0,
                        a / s
                    );
                }
            }
        }
    });
    println!(
        "\nexpected shape: advantage ≥ 1 everywhere and largest when the \
         communication tail is long relative to compute (many/large outputs, \
         short compute window) — the paper's motivation for Postmaster."
    );
    println!("\n[bench wall time {wall:.3} s]");
}
