//! E2 — §2.3 bandwidth claims: 432 GB/s per card; bisection 288 GB/s
//! (INC 3000) and 864 GB/s (INC 9000). Census + measured saturation.

mod common;

use inc_sim::config::SystemPreset;
use inc_sim::network::{Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::sim::MS;
use inc_sim::topology::{Coord, Topology};

/// Saturate the x-mid-plane of INC 3000 with pairwise traffic and
/// measure achieved cross-plane bandwidth.
fn measured_bisection_gbps(preset: SystemPreset, axis: usize) -> f64 {
    let mut net = Network::new(inc_sim::config::SystemConfig::new(preset));
    let dims = [net.topo.dims().0, net.topo.dims().1, net.topo.dims().2];
    let cut = dims[axis] / 2;
    let msg = 16 * 1024; // bytes per pair
    let mut pairs = 0u64;
    let coords: Vec<Coord> = net.topo.nodes().map(|n| net.topo.coord(n)).collect();
    for c in coords {
        if c.get(axis) == cut - 1 {
            // Partner directly across the plane, plus one further for
            // multi-span exercise.
            for d in [1u32, 3] {
                let mut p = c;
                let target = cut - 1 + d;
                if target < dims[axis] {
                    p = p.set(axis, target);
                    let (a, b) = (net.topo.id(c), net.topo.id(p));
                    for chunk in 0..(msg / 2048) {
                        let _ = chunk;
                        net.send_directed(
                            a,
                            b,
                            Proto::Raw { tag: 0 },
                            Payload::Synthetic(2040),
                        );
                    }
                    pairs += 1;
                }
            }
        }
    }
    let bytes = pairs * msg as u64;
    net.run_to_quiescence(&mut NullApp);
    let secs = net.now() as f64 / 1e9;
    bytes as f64 / secs / 1e9
}

fn main() {
    common::header("E2 / §2.3", "link census + bisection bandwidth");
    println!(
        "card port capacity: {} unidirectional links × 1 GB/s = {} GB/s (paper: 432 GB/s)",
        Topology::card_port_capacity(),
        Topology::card_port_capacity()
    );
    for (preset, paper) in [(SystemPreset::Inc3000, 288u32), (SystemPreset::Inc9000, 864)] {
        let t = Topology::preset(preset);
        println!(
            "{preset:?}: bisection census {} GB/s (paper: {paper} GB/s)",
            t.bisection_gbps()
        );
    }

    println!("\nmeasured cross-plane traffic (INC 3000, x mid-plane):");
    let (gbps, wall) = common::timed(|| measured_bisection_gbps(SystemPreset::Inc3000, 0));
    println!(
        "  achieved {gbps:.1} GB/s from one saturating wavefront \
         (census upper bound 288 GB/s)"
    );

    // Single-link sanity: 1 GB/s serialization.
    let mut net = Network::card();
    let (a, b) = (net.topo.id(Coord { x: 0, y: 0, z: 0 }), net.topo.id(Coord { x: 1, y: 0, z: 0 }));
    let t0 = net.now();
    for _ in 0..1000 {
        net.send_directed(a, b, Proto::Raw { tag: 0 }, Payload::Synthetic(2040));
    }
    net.run_to_quiescence(&mut NullApp);
    let bytes = 1000.0 * 2048.0;
    let secs = (net.now() - t0) as f64 / 1e9;
    println!(
        "  single link: {:.2} GB/s sustained (line rate 1 GB/s, paper §2.3)",
        bytes / secs / 1e9
    );
    assert!(net.now() < 100 * MS);
    println!("\n[bench wall time {wall:.3} s]");
}
