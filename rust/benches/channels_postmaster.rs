//! E5 — Fig 4: Postmaster DMA. Many-initiators → one-target small
//! messages; overhead vs the TCP/IP path; contiguity under load.

mod common;

use inc_sim::network::{Network, NullApp};
use inc_sim::topology::{Coord, NodeId};

fn main() {
    common::header("E5 / Fig 4", "Postmaster DMA tunneled queue");

    // Latency for one small record vs Ethernet for the same payload.
    println!("one 64 B payload, adjacent nodes:");
    let mut net = Network::card();
    let (a, b) = (NodeId(0), NodeId(1));
    net.pm_open(b, 0);
    net.pm_send(a, b, 0, vec![0; 64]);
    net.run_to_quiescence(&mut NullApp);
    let recs = net.pm_read(b, 0);
    let pm = (recs[0].t_stored - recs[0].t_enqueued).max(1);
    let mut net2 = Network::card();
    net2.eth_send(a, b, 64, 0);
    net2.run_to_quiescence(&mut NullApp);
    let eth = net2.metrics.packet_latency["eth_frame"].max();
    println!(
        "  postmaster {:.2} µs vs ethernet {:.2} µs -> {:.0}x lower overhead \
         (paper: \"much lower overhead than the TCP/IP stack\")",
        pm as f64 / 1000.0,
        eth as f64 / 1000.0,
        eth as f64 / pm as f64
    );

    // Fan-in sweep: 26 initiators stream records at one target.
    println!("\nfan-in: 26 initiators × N records of 64 B each:");
    println!("{:>6} {:>12} {:>14} {:>12}", "N", "records", "makespan µs", "rec/ms");
    let ((), wall) = common::timed(|| {
        for n in [1u32, 8, 32, 128] {
            let mut net = Network::card();
            let target = net.topo.id(Coord { x: 1, y: 1, z: 1 });
            net.pm_open(target, 0);
            for i in 0..27u32 {
                let src = NodeId(i);
                if src != target {
                    for k in 0..n {
                        net.pm_send(src, target, 0, vec![k as u8; 64]);
                    }
                }
            }
            net.run_to_quiescence(&mut NullApp);
            let recs = net.pm_read(target, 0);
            assert_eq!(recs.len(), 26 * n as usize);
            // Contiguity spot-check under the heaviest interleaving.
            for r in &recs {
                assert!(r.data.iter().all(|&x| x == r.data[0]), "torn record");
            }
            let makespan = net.now() as f64 / 1000.0;
            println!(
                "{:>6} {:>12} {:>14.1} {:>12.1}",
                n,
                recs.len(),
                makespan,
                recs.len() as f64 / (makespan / 1000.0)
            );
        }
    });

    // Record-size sweep.
    println!("\nrecord-size sweep (single initiator, 1000 records):");
    println!("{:>8} {:>14} {:>12}", "bytes", "makespan µs", "MB/s");
    for bytes in [16usize, 64, 256, 1024, 2040] {
        let mut net = Network::card();
        net.pm_open(NodeId(1), 0);
        for _ in 0..1000 {
            net.pm_send(NodeId(0), NodeId(1), 0, vec![7; bytes]);
        }
        net.run_to_quiescence(&mut NullApp);
        let secs = net.now() as f64 / 1e9;
        println!(
            "{:>8} {:>14.1} {:>12.1}",
            bytes,
            net.now() as f64 / 1000.0,
            1000.0 * bytes as f64 / secs / 1e6
        );
    }
    println!("\n[bench wall time {wall:.3} s]");
}
