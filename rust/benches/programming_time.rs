//! E7 — §4.3 programming-time comparison: JTAG vs PCIe+broadcast for
//! FPGA configuration and FLASH programming, at 1-card and 16-card
//! scale. The paper's numbers: 27 FPGAs ≈ 15 min over JTAG vs "a couple
//! of seconds" over PCIe; 27 FLASH chips > 5 h over JTAG vs ≈ 2 min;
//! 432 over PCIe ≈ identical to 27.

mod common;

use std::sync::Arc;

use inc_sim::network::Network;
use inc_sim::router::MemTarget;

fn main() {
    common::header("E7 / §4.3", "JTAG vs PCIe programming time (4 MiB images)");
    let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);

    println!(
        "{:<28} {:>14} {:>18}",
        "operation", "measured", "paper"
    );

    let ((), wall) = common::timed(|| {
        let mut net = Network::card();
        let t = net.jtag_program_fpgas((0, 0, 0), img.clone(), 1);
        println!(
            "{:<28} {:>10.1} min {:>18}",
            "JTAG  FPGA   x27 (1 card)",
            t as f64 / 60e9,
            "≈ 15 min"
        );

        let mut net = Network::card();
        let t = net.jtag_program_flash((0, 0, 0), img.clone());
        println!(
            "{:<28} {:>10.1} h   {:>18}",
            "JTAG  FLASH  x27 (1 card)",
            t as f64 / 3600e9,
            "> 5 h"
        );

        let mut net = Network::card();
        let t27 = net.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
        println!(
            "{:<28} {:>10.2} s   {:>18}",
            "PCIe  FPGA   x27 (1 card)",
            t27 as f64 / 1e9,
            "couple of seconds"
        );

        let mut net = Network::inc3000();
        let t432 = net.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
        println!(
            "{:<28} {:>10.2} s   {:>18}",
            "PCIe  FPGA   x432 (16 cards)",
            t432 as f64 / 1e9,
            "≈ same as 1 card"
        );
        println!(
            "{:<28} {:>10.3}x",
            "  432-vs-27 ratio",
            t432 as f64 / t27 as f64
        );

        for (label, preset) in [("x27", true), ("x432", false)] {
            let mut net = if preset { Network::card() } else { Network::inc3000() };
            let t = net.pcie_broadcast_program(MemTarget::Flash, img.clone(), 0);
            println!(
                "{:<28} {:>10.1} min {:>18}",
                format!("PCIe  FLASH  {label}"),
                t as f64 / 60e9,
                "≈ 2 min"
            );
        }

        // Speedup table.
        let mut net = Network::card();
        let jt = net.jtag_program_fpgas((0, 0, 0), img.clone(), 1);
        let mut net = Network::card();
        let pc = net.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
        println!(
            "\nPCIe-vs-JTAG speedup (FPGA, 1 card): {:.0}x (paper: ~15 min vs ~2 s ≈ 450x)",
            jt as f64 / pc as f64
        );
    });
    println!("\n[bench wall time {wall:.3} s]");
}
