//! E6 — Fig 5: Bridge FIFO datapath. Mux fan-in sweep (≤32 channels per
//! mux), width sweep (7..64 bits), and sustained word throughput.

mod common;

use inc_sim::network::{Network, NullApp};
use inc_sim::topology::{Coord, NodeId};

fn main() {
    common::header("E6 / Fig 5", "Bridge FIFO mux/demux datapath");

    // Channel fan-in: N concurrent FIFOs between the same node pair.
    println!("concurrent channels between one node pair, 1000 words each:");
    println!("{:>10} {:>14} {:>14}", "channels", "makespan µs", "Mword/s total");
    let ((), wall) = common::timed(|| {
        for ch in [1usize, 4, 16, 32] {
            let mut net = Network::card();
            let (a, b) = (NodeId(0), NodeId(1));
            for c in 0..ch as u8 {
                net.fifo_connect(a, b, c, 64);
            }
            let words: Vec<u64> = (0..1000).collect();
            for c in 0..ch as u8 {
                net.fifo_send(a, c, &words);
            }
            net.run_to_quiescence(&mut NullApp);
            for c in 0..ch as u8 {
                assert_eq!(net.fifo_read(b, c, usize::MAX).len(), 1000);
            }
            let secs = net.now() as f64 / 1e9;
            println!(
                "{:>10} {:>14.1} {:>14.2}",
                ch,
                net.now() as f64 / 1000.0,
                ch as f64 * 1000.0 / secs / 1e6
            );
        }
    });

    // Width sweep: narrow FIFOs mask words (7..64 bits supported).
    println!("\nwidth sweep (1000 words, adjacent nodes):");
    println!("{:>8} {:>16}", "bits", "mask check");
    for bits in [7u8, 16, 33, 64] {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(2));
        net.fifo_connect(a, b, 0, bits);
        net.fifo_send(a, 0, &[u64::MAX; 4]);
        net.run_to_quiescence(&mut NullApp);
        let got = net.fifo_read(b, 0, 4);
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        assert!(got.iter().all(|&w| w == mask));
        println!("{:>8} {:>16}", bits, format!("{:#x}", got[0]));
    }

    // Sustained throughput across the worst-case 6-hop path.
    let mut net = Network::card();
    let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let b = net.topo.id(Coord { x: 2, y: 2, z: 2 });
    net.fifo_connect(a, b, 0, 64);
    let words: Vec<u64> = (0..100_000).collect();
    net.fifo_send(a, 0, &words);
    net.run_to_quiescence(&mut NullApp);
    let n = net.fifo_read(b, 0, usize::MAX).len();
    let secs = net.now() as f64 / 1e9;
    println!(
        "\nsustained 6-hop stream: {} words in {:.2} ms = {:.1} MB/s \
         (line rate 1 GB/s; per-hop store-and-forward is the cost)",
        n,
        net.now() as f64 / 1e6,
        n as f64 * 8.0 / secs / 1e6
    );
    println!("\n[bench wall time {wall:.3} s]");
}
