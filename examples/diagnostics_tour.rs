//! Diagnostics tour (§4): a scripted PCIe Sandbox session that walks the
//! full bring-up story — program FPGAs, load kernels, boot, inspect.
//!
//! ```bash
//! cargo run --release --example diagnostics_tour
//! ```

use inc_sim::diag::sandbox::PcieSandbox;
use inc_sim::network::Network;
use inc_sim::node::regs;

fn main() {
    let mut net = Network::inc3000();
    let mut sb = PcieSandbox::attach((0, 0, 0));
    println!("attached PCIe Sandbox to node (000) of card (0,0,0) — INC 3000\n");

    for cmd in [
        "config",
        "program fpga 0xA1 4194304",
        "buildids",
        "loadall 0x8000 65536",
        "boot",
        "temps",
        "eeprom",
        "read 100 0xF0000028", // gateway node MAC id
        "write 222 0xF0000100 0x1234",
        "read 222 0xF0000100",
        "uart 000",
    ] {
        let out = sb.exec(&mut net, cmd);
        let text: String = out
            .text
            .lines()
            .take(6)
            .collect::<Vec<_>>()
            .join("\n");
        let more = out.text.lines().count().saturating_sub(6);
        println!("> {cmd}\n{text}");
        if more > 0 {
            println!("  … {more} more lines");
        }
        println!("  [{:.1} µs host time]\n", out.elapsed as f64 / 1000.0);
    }

    // JTAG comparison (§4.3): same images, painful path.
    let img = std::sync::Arc::new(vec![0u8; 4 * 1024 * 1024]);
    let t = net.jtag_program_fpgas((0, 0, 0), img.clone(), 0xA2);
    println!("JTAG FPGA programming, one card: {:.1} min (paper ≈ 15 min)", t as f64 / 60e9);
    let t = net.jtag_program_flash((0, 0, 0), img);
    println!("JTAG FLASH programming, one card: {:.1} h (paper > 5 h)", t as f64 / 3600e9);

    // Ring Bus direct read-all (what the sandbox uses underneath).
    let (temps, lat) = net.ring_read_all((0, 0, 0), net.topo.controller_node((0, 0, 0)), regs::TEMP);
    println!(
        "\nring bus read-all of {} temperature sensors in {} ns",
        temps.len(),
        lat
    );
}
