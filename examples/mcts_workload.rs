//! Distributed MCTS (intro + experiment E9): the paper's example of an
//! algorithm that does not map to SIMD hardware but maps naturally to
//! INC's mesh of independent nodes exchanging small messages.
//!
//! ```bash
//! cargo run --release --example mcts_workload
//! ```

use inc_sim::network::Network;
use inc_sim::topology::NodeId;
use inc_sim::workload::mcts::{DistributedMcts, Game};

fn main() {
    println!("distributed MCTS over Postmaster DMA (leader at node 000)\n");
    println!(
        "{:>8} {:>10} {:>12} {:>16} {:>10}",
        "workers", "rollouts", "makespan ms", "rollouts/s", "found?"
    );
    for workers in [1usize, 2, 4, 8, 16, 26] {
        let mut net = Network::card();
        let leader = NodeId(0);
        let ws: Vec<NodeId> = (1..=workers as u32).map(NodeId).collect();
        let game = Game { depth: 6, branching: 3, seed: 42 };
        let mcts = DistributedMcts::new(&mut net, game, leader, ws);
        let r = mcts.search(&mut net, 4000);
        println!(
            "{:>8} {:>10} {:>12.2} {:>16.0} {:>10}",
            workers,
            r.rollouts,
            r.makespan as f64 / 1e6,
            r.throughput,
            if r.best_path == vec![0; 6] { "yes" } else { "no" }
        );
    }
    println!("\n('found?' = recovered the planted optimal action path)");
}
