//! Quickstart: build an INC card, exercise all three virtual channels,
//! and print the fabric metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use inc_sim::network::{Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::topology::Coord;

fn main() {
    // One INC card: 27 Zynq nodes in a 3×3×3 mesh (Fig 1).
    let mut net = Network::card();
    println!(
        "built a {}-node card; {} unidirectional SERDES links",
        net.topo.node_count(),
        net.topo.link_count()
    );

    let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let b = net.topo.id(Coord { x: 2, y: 2, z: 2 });

    // 1. Raw directed packet, adaptively routed (§2.4).
    net.send_directed(a, b, Proto::Raw { tag: 1 }, Payload::bytes(vec![7; 256]));

    // 2. Broadcast: one copy to every node (§2.4).
    net.send_broadcast(a, Proto::Raw { tag: 2 }, Payload::Empty);

    // 3. Bridge FIFO: lowest-latency FPGA-to-FPGA words (§3.3).
    net.fifo_connect(a, b, 0, 64);
    net.fifo_send(a, 0, &[0xFEED, 0xBEEF]);

    // 4. Postmaster DMA: small records into a receive stream (§3.2).
    net.pm_open(b, 0);
    net.pm_send(a, b, 0, b"hello from node 000".to_vec());

    // 5. Internal Ethernet: full software path (§3.1).
    net.eth_send(a, b, 1400, 42);

    net.run_to_quiescence(&mut NullApp);

    println!("\nafter {} ns of virtual time:", net.now());
    println!("  bridge fifo words at {b}: {:?}", net.fifo_read(b, 0, 8));
    let recs = net.pm_read(b, 0);
    println!(
        "  postmaster record: {:?}",
        String::from_utf8_lossy(&recs[0].data)
    );
    println!("  ethernet frames: {}", net.eth_read(b).len());
    println!("\n{}", net.metrics.report());
}
