//! E10 — the end-to-end driver: data-parallel training of the
//! JAX/Pallas transformer LM over the simulated INC card.
//!
//! All three layers compose here:
//!  * L1/L2: AOT-compiled Pallas kernels + transformer (artifacts/),
//!    executed through PJRT — real numerics, Python not running;
//!  * L3: the Rust coordinator places 8 ranks on mesh nodes, charges
//!    each grad step to the node's FPGA compute model, and all-reduces
//!    gradients as real packets over the simulated fabric.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_distributed
//! ```

use inc_sim::coordinator::Placement;
use inc_sim::network::Network;
use inc_sim::workload::training::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let rt = inc_sim::runtime::load_default()?;
    println!(
        "loaded {} ({} entry points) on PJRT [{}]",
        rt.manifest.model,
        rt.manifest.entries.len(),
        rt.platform()
    );

    let mut net = Network::card();
    let cfg = TrainConfig {
        ranks: 8,
        steps: 300,
        lr: 0.25,
        seed: 7,
        placement: Placement::Block,
        log_every: 20,
        ..Default::default()
    };
    println!(
        "training {} ranks × {} steps on a 27-node card…\n",
        cfg.ranks, cfg.steps
    );
    let t0 = std::time::Instant::now();
    let report = train(&mut net, &rt, &cfg)?;
    let wall = t0.elapsed();

    println!("{:>6} {:>10} {:>14}", "step", "loss", "virtual ms");
    for p in &report.curve {
        println!("{:>6} {:>10.4} {:>14.3}", p.step, p.loss, p.vtime as f64 / 1e6);
    }
    println!(
        "\nloss: {:.4} -> {:.4} ({} params)",
        report.first_loss, report.final_loss, report.params
    );
    println!(
        "virtual time: {:.1} ms  ({:.1}% compute, {:.1}% gradient all-reduce)",
        report.vtime_total as f64 / 1e6,
        report.vtime_compute as f64 / report.vtime_total as f64 * 100.0,
        report.vtime_comm as f64 / report.vtime_total as f64 * 100.0
    );
    println!(
        "gradient all-reduce: {:.2} MB per step over the mesh",
        report.grad_bytes as f64 / 1e6
    );
    println!("wall clock: {:.1} s", wall.as_secs_f64());
    Ok(())
}
