//! Distributed learners (§3.2, experiment E8): quantify how much
//! sending outputs *as they are generated* (Postmaster's design point)
//! beats aggregating them until the end of a time step.
//!
//! ```bash
//! cargo run --release --example learners_overlap
//! ```

use inc_sim::network::Network;
use inc_sim::workload::learners::{overlap_advantage, LearnerConfig, SendStrategy};

fn main() {
    println!("distributed learners over Postmaster DMA (paper §3.2)\n");
    println!(
        "{:>8} {:>8} {:>14} {:>14} {:>10}",
        "outputs", "bytes", "streamed µs", "aggregated µs", "advantage"
    );
    for outputs in [4, 16, 64] {
        for bytes in [32, 256] {
            let cfg = LearnerConfig {
                learners: 27,
                outputs_per_step: outputs,
                record_bytes: bytes,
                compute_ns: 50_000,
                steps: 3,
                ..LearnerConfig::default()
            };
            let (s, a) = overlap_advantage(Network::card, cfg);
            println!(
                "{:>8} {:>8} {:>14.1} {:>14.1} {:>9.2}x",
                outputs,
                bytes,
                s / 1000.0,
                a / 1000.0,
                a / s
            );
        }
    }

    // One detailed run for the curious.
    let cfg = LearnerConfig::default();
    let mut net = Network::card();
    let stats = inc_sim::workload::learners::run(&mut net, cfg, SendStrategy::Streamed);
    println!(
        "\nstreamed, per step: {:?} µs ({} records/step)",
        stats.iter().map(|s| s.makespan / 1000).collect::<Vec<_>>(),
        stats[0].records
    );
    println!("\n{}", net.metrics.report());
}
