//! First-class communication modes: one generic function, every
//! virtual channel.
//!
//! Opens a pair of endpoints on each of the three paper channels —
//! Postmaster DMA (§3.2), internal Ethernet (§3.1), Bridge FIFO (§3.3)
//! — plus the NetTunnel register mailbox (§4.2), pushes the same
//! message schedule through each, and prints the capability descriptor
//! next to the measured round time: Table 1 as running code.
//!
//! ```bash
//! cargo run --release --example comm_modes
//! ```

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::{CommMode, Message};
use inc_sim::network::{Fabric, Network, NullApp};
use inc_sim::topology::{Coord, NodeId};

/// The mode-generic exchange: `n` messages of `bytes` each from `a` to
/// `b`, returning the virtual time the exchange took. Nothing in here
/// names a channel — the mode is data.
fn exchange<F: Fabric>(net: &mut F, mode: CommMode, a: NodeId, b: NodeId, n: u32, bytes: usize) -> u64 {
    let ea = net.open(a, mode);
    let eb = net.open(b, mode);
    if net.caps(mode).pair_setup {
        net.connect(&ea, b);
    }
    let t0 = net.now();
    for i in 0..n {
        net.send(&ea, b, Message::new(vec![i as u8; bytes]));
    }
    net.run(&mut NullApp);
    let got = net.recv(&eb);
    assert_eq!(got.len(), n as usize, "lost messages on {}", mode.name());
    net.now() - t0
}

fn main() {
    let modes = [
        CommMode::BridgeFifo { width_bits: 64 },
        CommMode::Postmaster { queue: 0 },
        CommMode::Ethernet { rx: RxMode::Interrupt },
        CommMode::Tunnel { addr: inc_sim::node::regs::SCRATCH0 },
    ];
    println!("16 x 8 B messages across the card diagonal, per communication mode:\n");
    println!(
        "{:<12} {:>10} {:>12} {:>10} {:>12} {:>12}",
        "mode", "round µs", "latency", "ordering", "max payload", "pair setup"
    );
    for mode in modes {
        let mut net = Network::card();
        let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let b = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        let caps = Fabric::caps(&net, mode);
        let t = exchange(&mut net, mode, a, b, 16, 8);
        println!(
            "{:<12} {:>10.2} {:>12} {:>10} {:>12} {:>12}",
            mode.name(),
            t as f64 / 1000.0,
            format!("{:?}", caps.latency),
            match caps.ordering {
                inc_sim::channels::MsgOrdering::PerPairFifo => "fifo",
                inc_sim::channels::MsgOrdering::Unordered => "unordered",
            },
            caps.max_payload.map_or("none".to_string(), |m| format!("{m} B")),
            if caps.pair_setup { "required" } else { "-" },
        );
    }
    println!(
        "\nSame workload code, four transports — the mode is a value \
         (CommMode), its guarantees a descriptor (ChannelCaps)."
    );
}
