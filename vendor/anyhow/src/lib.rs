//! Minimal offline stand-in for the crates.io `anyhow` crate.
//!
//! The build environment has no network access, so this vendored shim
//! provides exactly the subset `inc_sim` uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the [`anyhow!`] / [`bail!`]
//! macros. Error values are flat strings (context is prepended with
//! `": "` separators) rather than a source chain — enough for clear
//! diagnostics in tests and CLI output.

use std::fmt;

/// A string-backed error value.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line (used by [`Context`]).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // exercises From<ParseIntError>
        if n == 0 {
            bail!("zero is not allowed");
        }
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("0").unwrap_err().to_string(), "zero is not allowed");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn context_chains() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| "reading manifest".to_string()).unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let o: Option<u8> = None;
        assert!(o.context("missing").is_err());
    }
}
