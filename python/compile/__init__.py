"""Build-time-only package: JAX/Pallas model + AOT lowering to HLO text.

Never imported at simulation time — the Rust binary consumes only the
``artifacts/`` this package emits (see ``aot.py``).
"""
