"""AOT lowering: JAX/Pallas model -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
results through PJRT and Python never appears on the simulation path.

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowering uses ``return_tuple=True``; the
Rust side untuples.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import CFG, PARAM_NAMES


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape):
    return {"name": name, "shape": list(shape), "dtype": "f32"}


def build_entries(cfg=CFG):
    """(name, lowered, input specs, output specs) for every entry point."""
    shapes = dict(model.param_shapes(cfg))
    p_specs = [spec(f"p:{n}", shapes[n]) for n in PARAM_NAMES]
    g_specs = [spec(f"g:{n}", shapes[n]) for n in PARAM_NAMES]
    x_spec = spec("x", (cfg.batch, cfg.seq))
    y_spec = spec("y", (cfg.batch, cfg.seq))

    p_args = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in PARAM_NAMES]
    xy = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.float32)
    lr = jax.ShapeDtypeStruct((1,), jnp.float32)

    entries = []

    # init: () -> params
    init_fn = lambda: model.init(cfg)
    entries.append(
        ("init", jax.jit(init_fn).lower(), [], p_specs)
    )

    # grad: (params..., x, y) -> (loss, grads...)
    def grad_fn(*args):
        params, x, y = args[: len(PARAM_NAMES)], args[-2], args[-1]
        return model.grad(tuple(params), x, y, cfg)

    entries.append(
        (
            "grad",
            jax.jit(grad_fn).lower(*p_args, xy, xy),
            p_specs + [x_spec, y_spec],
            [spec("loss", (1,))] + g_specs,
        )
    )

    # apply: (params..., grads..., lr) -> params'
    def apply_fn(*args):
        return model.apply(args, cfg)

    entries.append(
        (
            "apply",
            jax.jit(apply_fn).lower(*p_args, *p_args, lr),
            p_specs + g_specs + [spec("lr", (1,))],
            p_specs,
        )
    )

    # fwd: (params..., x) -> logits   (serving/inspection path)
    def fwd_fn(*args):
        return (model.forward(tuple(args[:-1]), args[-1], cfg),)

    entries.append(
        (
            "fwd",
            jax.jit(fwd_fn).lower(*p_args, xy),
            p_specs + [x_spec],
            [spec("logits", (cfg.batch, cfg.seq, cfg.vocab))],
        )
    )

    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"model": CFG.name, "entries": []}
    for name, lowered, inputs, outputs in build_entries():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}
        )
        print(f"wrote {fname} ({len(text) / 1e6:.2f} MB, "
              f"{len(inputs)} in / {len(outputs)} out)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json (model {CFG.name})")


if __name__ == "__main__":
    main()
