"""L1 Pallas kernel: distributed-learner state update (§3.2 workload).

Each learner keeps a leaky-integrator state updated from the small
records its peers sent last time step:

    state' = decay * state + (1 - decay) * tanh(inputs @ w)

The grid dimension walks learner tiles — the direct analog of the paper
distributing learners across mesh nodes. ``interpret=True`` (see
fused_dense.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_L = 8  # learners per grid step


def _learner_kernel(state_ref, inputs_ref, w_ref, o_ref, *, decay: float):
    s = state_ref[...]
    x = inputs_ref[...]
    w = w_ref[...]
    drive = jnp.tanh(jnp.dot(x, w, preferred_element_type=jnp.float32))
    o_ref[...] = (decay * s + (1.0 - decay) * drive).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("decay",))
def learner_update(state, inputs, w, decay: float = 0.9):
    """state: [L, D], inputs: [L, K], w: [K, D] -> [L, D]."""
    l, d = state.shape
    l2, k = inputs.shape
    assert l == l2 and w.shape == (k, d)
    tile = min(TILE_L, l)
    pad = (-l) % tile
    if pad:
        state = jnp.pad(state, ((0, pad), (0, 0)))
        inputs = jnp.pad(inputs, ((0, pad), (0, 0)))
    grid = ((l + pad) // tile,)
    out = pl.pallas_call(
        functools.partial(_learner_kernel, decay=decay),
        out_shape=jax.ShapeDtypeStruct((l + pad, d), state.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        interpret=True,
    )(state, inputs, w)
    return out[:l]
