"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle to float32 tolerance
across the shape/dtype sweeps in ``python/tests/`` — this is the L1
correctness signal the AOT artifacts inherit.
"""

import jax
import jax.numpy as jnp


def fused_dense_ref(x, w, b, activation: str = "gelu"):
    acc = x @ w + b[None, :]
    if activation == "gelu":
        return jax.nn.gelu(acc)
    if activation == "relu":
        return jnp.maximum(acc, 0.0)
    if activation == "none":
        return acc
    raise ValueError(f"unknown activation {activation}")


def causal_attention_ref(q, k, v):
    b, h, t, dh = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    )
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def learner_update_ref(state, inputs, w, decay: float = 0.9):
    return decay * state + (1.0 - decay) * jnp.tanh(inputs @ w)
