"""L1 Pallas kernel: fused dense layer — ``act(x @ w + b)``.

The compute hot-spot of the per-node workload (the transformer MLP and
the attention projections). On the real INC this is the kind of operator
one would offload to the Zynq FPGA fabric; here it is re-thought for a
TPU-style target per the hardware-adaptation rule:

* the grid walks row tiles of ``x`` (``TILE_M`` rows at a time) — the
  BlockSpec expresses the HBM->VMEM staging the FPGA design would do
  with BRAM;
* the weight block is kept whole per grid step (model dims in this repo
  are <= 256, well inside VMEM);
* matmul shapes are padded by the caller to multiples of the MXU tile
  where it matters (see DESIGN.md §Hardware-Adaptation).

``pallas_call`` has no reverse-mode rule, so the public entry point is a
``jax.custom_vjp``: the backward pass recomputes the pre-activation and
routes all three backward matmuls (dx, dw and the recompute) through the
same Pallas kernel — the hot path stays on the kernel in both
directions. ``interpret=True`` everywhere: the CPU PJRT client cannot
execute Mosaic custom-calls; correctness is validated against ``ref.py``
by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size: one MXU-aligned stripe of activations per grid step.
TILE_M = 128


def _act(z, activation: str):
    if activation == "gelu":
        return jax.nn.gelu(z)
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "none":
        return z
    raise ValueError(f"unknown activation {activation}")


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = _act(acc, activation).astype(o_ref.dtype)


def _pallas_dense(x, w, b, activation: str):
    """The raw row-tiled pallas_call (no AD)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    tile_m = min(TILE_M, m)
    pad = (-m) % tile_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((m + pad) // tile_m,)
    out = pl.pallas_call(
        functools.partial(_fused_dense_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((m + pad, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
        interpret=True,
    )(x, w, b)
    return out[:m]


@functools.lru_cache(maxsize=None)
def _make_fused_dense(activation: str):
    @jax.custom_vjp
    def f(x, w, b):
        return _pallas_dense(x, w, b, activation)

    def fwd(x, w, b):
        return _pallas_dense(x, w, b, activation), (x, w, b)

    def bwd(res, dy):
        x, w, b = res
        if activation == "none":
            dz = dy
        else:
            # Recompute the pre-activation through the kernel, then chain
            # through the activation.
            z = _pallas_dense(x, w, b, "none")
            _, act_vjp = jax.vjp(lambda t: _act(t, activation), z)
            (dz,) = act_vjp(dy)
        n = w.shape[1]
        k = w.shape[0]
        zeros_k = jnp.zeros((k,), x.dtype)
        zeros_n = jnp.zeros((n,), x.dtype)
        dx = _pallas_dense(dz, w.T, zeros_k, "none")
        dw = _pallas_dense(x.T, dz, zeros_n, "none")
        db = jnp.sum(dz, axis=0)
        return dx, dw, db

    f.defvjp(fwd, bwd)
    return f


def fused_dense(x, w, b, activation: str = "gelu"):
    """``act(x @ w + b)`` with a row-tiled Pallas kernel (differentiable).

    x: [M, K], w: [K, N], b: [N] -> [M, N].
    """
    if activation not in ("gelu", "relu", "none"):
        raise ValueError(f"unknown activation {activation}")
    return _make_fused_dense(activation)(x, w, b)
