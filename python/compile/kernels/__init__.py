"""L1: Pallas kernels for the per-node compute hot-spots + jnp oracles."""

from .attention import causal_attention
from .fused_dense import fused_dense
from .learner_update import learner_update

__all__ = ["causal_attention", "fused_dense", "learner_update"]
