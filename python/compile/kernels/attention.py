"""L1 Pallas kernel: fused causal attention.

One grid step processes one (batch, head) pair entirely in VMEM:
``softmax(mask(q @ k^T / sqrt(d))) @ v``. Sequence lengths in this repo
are small (<= 128), so the whole [T, T] score tile fits comfortably —
the BlockSpec keeps q/k/v for the (b, h) pair resident, the TPU analog
of keeping the working set in FPGA BRAM.

``pallas_call`` has no reverse-mode rule; the public entry point is a
``jax.custom_vjp`` whose backward uses the standard softmax-attention
gradients (einsum form — they are matmul-bound and XLA fuses them).
``interpret=True`` (see fused_dense.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    # Blocks arrive as [1, 1, T, Dh] — drop the leading grid dims.
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    t, dh = q.shape
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(col <= row, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o_ref[0, 0] = jnp.dot(probs, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _pallas_attention(q, k, v):
    b, h, t, dh = q.shape
    assert k.shape == v.shape == (b, h, t, dh)
    spec = pl.BlockSpec((1, 1, t, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(q, k, v)


def _probs(q, k):
    b, h, t, dh = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    )
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


@jax.custom_vjp
def causal_attention(q, k, v):
    """Fused causal attention. q/k/v: [B, H, T, Dh] -> [B, H, T, Dh]."""
    return _pallas_attention(q, k, v)


def _fwd(q, k, v):
    return _pallas_attention(q, k, v), (q, k, v)


def _bwd(res, do):
    q, k, v = res
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    p = _probs(q, k)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, v)
    # Softmax jacobian: ds = p * (dp - sum(dp * p)).
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_fwd, _bwd)
