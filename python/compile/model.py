"""L2: the per-node JAX model — a tiny transformer LM.

This is the "machine intelligence application" running on the simulated
INC: each mesh node holds a replica and trains data-parallel, with
gradients exchanged over the simulated fabric (Rust side). The forward
pass routes its hot-spots through the L1 Pallas kernels
(``kernels.fused_dense`` for projections/MLP, ``kernels.causal_attention``
for attention), so the AOT artifacts exercise all three layers.

Entry points AOT-compiled by ``aot.py`` (the contract with
``rust/src/workload/training.rs``):

* ``init()  -> params``                      (deterministic)
* ``grad(params, x, y) -> (loss, grads)``    (x/y are f32 token ids)
* ``apply(params, grads, lr) -> params'``    (plain SGD)

Parameters are an ordered list of named tensors (see ``PARAM_NAMES``);
ordering is part of the contract and is recorded in the manifest.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import causal_attention, fused_dense


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 64
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    seq: int = 16
    batch: int = 8
    d_ff: int = 256

    @property
    def name(self) -> str:
        return (
            f"tiny-lm-d{self.d_model}-l{self.n_layers}-h{self.n_heads}"
            f"-t{self.seq}-b{self.batch}-v{self.vocab}"
        )


CFG = ModelConfig()


def param_shapes(cfg: ModelConfig = CFG):
    """Ordered (name, shape) list — the AOT tensor contract."""
    shapes = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.bo", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.b1", (cfg.d_ff,)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.b2", (cfg.d_model,)),
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.ln2", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
        ("head_b", (cfg.vocab,)),
    ]
    return shapes


PARAM_NAMES = [n for n, _ in param_shapes()]


def init(cfg: ModelConfig = CFG):
    """Deterministic parameter init (seeded; scaled normals, ones for LN)."""
    key = jax.random.PRNGKey(20200417)  # the paper's arXiv year+month :-)
    params = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "lnf":
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("b1", "b2", "bo", "head_b")) or name == "head_b":
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 0.5 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return tuple(params)


def _rms_norm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def forward(params, x_tokens, cfg: ModelConfig = CFG):
    """Logits for next-token prediction. x_tokens: f32 [B, T] token ids."""
    p = dict(zip(PARAM_NAMES, params))
    b, t = x_tokens.shape
    ids = x_tokens.astype(jnp.int32)
    h = p["tok_emb"][ids] + p["pos_emb"][None, :t, :]
    dh = cfg.d_model // cfg.n_heads
    for i in range(cfg.n_layers):
        hn = _rms_norm(h, p[f"l{i}.ln1"])
        flat = hn.reshape(b * t, cfg.d_model)
        # Q/K/V projections through the fused-dense Pallas kernel.
        q = fused_dense(flat, p[f"l{i}.wq"], jnp.zeros(cfg.d_model), "none")
        k = fused_dense(flat, p[f"l{i}.wk"], jnp.zeros(cfg.d_model), "none")
        v = fused_dense(flat, p[f"l{i}.wv"], jnp.zeros(cfg.d_model), "none")
        split = lambda z: z.reshape(b, t, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        att = causal_attention(split(q), split(k), split(v))
        att = att.transpose(0, 2, 1, 3).reshape(b * t, cfg.d_model)
        att = fused_dense(att, p[f"l{i}.wo"], p[f"l{i}.bo"], "none")
        h = h + att.reshape(b, t, cfg.d_model)
        # MLP through the fused-dense kernel (gelu inside the kernel).
        hn = _rms_norm(h, p[f"l{i}.ln2"]).reshape(b * t, cfg.d_model)
        up = fused_dense(hn, p[f"l{i}.w1"], p[f"l{i}.b1"], "gelu")
        down = fused_dense(up, p[f"l{i}.w2"], p[f"l{i}.b2"], "none")
        h = h + down.reshape(b, t, cfg.d_model)
    h = _rms_norm(h, p["lnf"])
    logits = h.reshape(b * t, cfg.d_model) @ p["head"] + p["head_b"]
    return logits.reshape(b, t, cfg.vocab)


def loss_fn(params, x, y, cfg: ModelConfig = CFG):
    """Mean next-token cross-entropy. x/y: f32 [B, T]."""
    logits = forward(params, x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = y.astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def grad(params, x, y, cfg: ModelConfig = CFG):
    """(loss, grads) — the per-rank training step body."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
    return (jnp.reshape(loss, (1,)), *grads)


def apply(params_and_grads_and_lr, cfg: ModelConfig = CFG):
    """SGD update. Input: params..., grads..., lr[1]. Output: params'."""
    n = len(PARAM_NAMES)
    params = params_and_grads_and_lr[:n]
    grads = params_and_grads_and_lr[n : 2 * n]
    lr = params_and_grads_and_lr[2 * n][0]
    return tuple(p - lr * g for p, g in zip(params, grads))


def pure_jnp_forward(params, x_tokens, cfg: ModelConfig = CFG):
    """Oracle forward: same math with jnp ops only (no Pallas). Used by
    tests to validate the kernel-routed forward end to end."""
    from .kernels.ref import causal_attention_ref, fused_dense_ref

    p = dict(zip(PARAM_NAMES, params))
    b, t = x_tokens.shape
    ids = x_tokens.astype(jnp.int32)
    h = p["tok_emb"][ids] + p["pos_emb"][None, :t, :]
    dh = cfg.d_model // cfg.n_heads
    for i in range(cfg.n_layers):
        hn = _rms_norm(h, p[f"l{i}.ln1"])
        flat = hn.reshape(b * t, cfg.d_model)
        q = fused_dense_ref(flat, p[f"l{i}.wq"], jnp.zeros(cfg.d_model), "none")
        k = fused_dense_ref(flat, p[f"l{i}.wk"], jnp.zeros(cfg.d_model), "none")
        v = fused_dense_ref(flat, p[f"l{i}.wv"], jnp.zeros(cfg.d_model), "none")
        split = lambda z: z.reshape(b, t, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        att = causal_attention_ref(split(q), split(k), split(v))
        att = att.transpose(0, 2, 1, 3).reshape(b * t, cfg.d_model)
        att = fused_dense_ref(att, p[f"l{i}.wo"], p[f"l{i}.bo"], "none")
        h = h + att.reshape(b, t, cfg.d_model)
        hn = _rms_norm(h, p[f"l{i}.ln2"]).reshape(b * t, cfg.d_model)
        up = fused_dense_ref(hn, p[f"l{i}.w1"], p[f"l{i}.b1"], "gelu")
        down = fused_dense_ref(up, p[f"l{i}.w2"], p[f"l{i}.b2"], "none")
        h = h + down.reshape(b, t, cfg.d_model)
    h = _rms_norm(h, p["lnf"])
    logits = h.reshape(b * t, cfg.d_model) @ p["head"] + p["head_b"]
    return logits.reshape(b, t, cfg.vocab)
