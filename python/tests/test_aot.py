"""AOT pipeline checks: HLO text emission + manifest integrity."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.model import CFG, PARAM_NAMES

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_roundtrips_a_small_function():
    f = lambda a, b: (a @ b + 2.0,)
    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(f).lower(s, s))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_build_entries_contract(tmp_path):
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    assert names == ["init", "grad", "apply", "fwd"]
    by_name = {e[0]: e for e in entries}

    _, _, g_in, g_out = by_name["grad"]
    assert len(g_in) == len(PARAM_NAMES) + 2
    assert g_in[-2]["name"] == "x" and g_in[-1]["name"] == "y"
    assert g_out[0]["name"] == "loss" and g_out[0]["shape"] == [1]
    assert len(g_out) == 1 + len(PARAM_NAMES)

    _, _, a_in, a_out = by_name["apply"]
    assert len(a_in) == 2 * len(PARAM_NAMES) + 1
    assert a_in[-1]["name"] == "lr"
    assert len(a_out) == len(PARAM_NAMES)

    _, _, i_in, i_out = by_name["init"]
    assert i_in == [] and len(i_out) == len(PARAM_NAMES)

    # Every entry lowers to parseable HLO text.
    for name, lowered, _, _ in entries:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name


def test_manifest_specs_match_param_shapes():
    shapes = dict(model.param_shapes())
    entries = aot.build_entries()
    _, _, g_in, _ = [e for e in entries if e[0] == "grad"][0]
    for s in g_in[: len(PARAM_NAMES)]:
        name = s["name"].removeprefix("p:")
        assert tuple(s["shape"]) == shapes[name]
    assert g_in[len(PARAM_NAMES)]["shape"] == [CFG.batch, CFG.seq]
