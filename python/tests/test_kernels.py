"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the kernels' static knobs); numerics are
checked with float32 tolerances. These tests are the contract the AOT
artifacts inherit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import causal_attention, fused_dense, learner_update
from compile.kernels.ref import (
    causal_attention_ref,
    fused_dense_ref,
    learner_update_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- fused_dense

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(["gelu", "relu", "none"]),
)
def test_fused_dense_matches_ref(m, k, n, act):
    x, w, b = rand(1, m, k), rand(2, k, n), rand(3, n)
    got = fused_dense(x, w, b, act)
    want = fused_dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fused_dense_exact_tile_boundary():
    # m exactly a multiple of the tile and m = tile ± 1.
    for m in (128, 127, 129, 256):
        x, w, b = rand(4, m, 64), rand(5, 64, 64), rand(6, 64)
        np.testing.assert_allclose(
            fused_dense(x, w, b, "gelu"),
            fused_dense_ref(x, w, b, "gelu"),
            rtol=2e-5,
            atol=2e-5,
        )


def test_fused_dense_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        fused_dense(rand(1, 4, 8), rand(2, 9, 3), rand(3, 3))
    with pytest.raises(AssertionError):
        fused_dense(rand(1, 4, 8), rand(2, 8, 3), rand(3, 4))


def test_fused_dense_unknown_activation():
    with pytest.raises(ValueError):
        fused_dense(rand(1, 4, 8), rand(2, 8, 3), rand(3, 3), "swish")


# ----------------------------------------------------------------- attention

@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    t=st.integers(1, 32),
    dh=st.sampled_from([8, 16, 32]),
)
def test_attention_matches_ref(b, h, t, dh):
    q, k, v = rand(7, b, h, t, dh), rand(8, b, h, t, dh), rand(9, b, h, t, dh)
    got = causal_attention(q, k, v)
    want = causal_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_is_causal():
    # Changing a future key/value must not change earlier outputs.
    b, h, t, dh = 1, 1, 8, 16
    q, k, v = rand(10, b, h, t, dh), rand(11, b, h, t, dh), rand(12, b, h, t, dh)
    base = causal_attention(q, k, v)
    k2 = k.at[0, 0, -1].add(100.0)
    v2 = v.at[0, 0, -1].add(-50.0)
    pert = causal_attention(q, k2, v2)
    np.testing.assert_allclose(base[0, 0, :-1], pert[0, 0, :-1], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[0, 0, -1], pert[0, 0, -1])


# ------------------------------------------------------------- learner update

@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(1, 40),
    d=st.integers(1, 48),
    k=st.integers(1, 48),
    decay=st.floats(0.0, 1.0),
)
def test_learner_update_matches_ref(l, d, k, decay):
    s, x, w = rand(13, l, d), rand(14, l, k), rand(15, k, d)
    got = learner_update(s, x, w, decay)
    want = learner_update_ref(s, x, w, decay)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_learner_update_decay_extremes():
    s, x, w = rand(16, 8, 8), rand(17, 8, 8), rand(18, 8, 8)
    # decay=1: state unchanged.
    np.testing.assert_allclose(learner_update(s, x, w, 1.0), s, rtol=1e-6)
    # decay=0: pure drive.
    np.testing.assert_allclose(
        learner_update(s, x, w, 0.0), jnp.tanh(x @ w), rtol=2e-5, atol=2e-5
    )
