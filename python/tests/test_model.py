"""L2 correctness: the kernel-routed model vs the pure-jnp oracle model,
plus shape/contract checks for the AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.model import CFG, PARAM_NAMES

jax.config.update("jax_platform_name", "cpu")


def tokens(key, b, t):
    return jax.random.randint(
        jax.random.PRNGKey(key), (b, t), 0, CFG.vocab
    ).astype(jnp.float32)


def test_param_shapes_cover_names():
    shapes = model.param_shapes()
    assert [n for n, _ in shapes] == PARAM_NAMES
    params = model.init()
    assert len(params) == len(PARAM_NAMES)
    for (name, shape), p in zip(shapes, params):
        assert p.shape == shape, name


def test_init_is_deterministic():
    a, b = model.init(), model.init()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_forward_matches_pure_jnp_oracle():
    params = model.init()
    x = tokens(1, CFG.batch, CFG.seq)
    got = model.forward(params, x)
    want = model.pure_jnp_forward(params, x)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_loss_is_scalar_and_near_uniform_at_init():
    params = model.init()
    x, y = tokens(2, CFG.batch, CFG.seq), tokens(3, CFG.batch, CFG.seq)
    loss = model.loss_fn(params, x, y)
    assert loss.shape == ()
    # Near-uniform predictions at init: loss ≈ ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_grad_output_layout():
    params = model.init()
    x, y = tokens(4, CFG.batch, CFG.seq), tokens(5, CFG.batch, CFG.seq)
    out = model.grad(params, x, y)
    assert len(out) == 1 + len(PARAM_NAMES)
    assert out[0].shape == (1,)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape


def test_apply_is_sgd():
    params = model.init()
    grads = tuple(jnp.ones_like(p) for p in params)
    lr = jnp.asarray([0.5], jnp.float32)
    new = model.apply(params + grads + (lr,))
    for p, n in zip(params, new):
        np.testing.assert_allclose(n, p - 0.5, rtol=1e-6, atol=1e-6)


def test_three_sgd_steps_reduce_loss():
    # The whole L2 training contract, in miniature.
    params = model.init()
    x = tokens(6, CFG.batch, CFG.seq)
    y = jnp.roll(x, -1, axis=1)  # learnable shift task
    lr = jnp.asarray([0.5], jnp.float32)
    losses = []
    for _ in range(3):
        out = model.grad(params, x, y)
        losses.append(float(out[0][0]))
        params = model.apply(tuple(params) + tuple(out[1:]) + (lr,))
    assert losses[-1] < losses[0], losses
